//! Differential conformance battery: every layer of the packed-arithmetic
//! stack checked against an independent oracle.
//!
//! * the exhaustive INT4 differential pins §V to the default test run:
//!   full correction is exact on **every** operand pair, and the
//!   uncorrected scheme reproduces the paper's Table I/II error figures;
//! * randomized codec roundtrips pin "packed planes carry the full
//!   operand information" across generated configurations;
//! * the plan/execute/matmul triangle is checked on random matrices for
//!   every preset packing × correction mode: `execute(plan(W), X)` must
//!   be bit-identical to `matmul(X, W)` always, and both equal the exact
//!   i32 reference for the schemes the paper proves (or we measured)
//!   exact.

use dsp_packing::analysis::ErrorStats;
use dsp_packing::correct::Correction;
use dsp_packing::gemm::{GemmEngine, KernelMode, MatI32, WordBackend};
use dsp_packing::packing::{PackedMultiplier, Packer, PackingConfig};
use dsp_packing::util::Rng;

/// The preset configurations the differential suites sweep.
fn presets() -> Vec<(&'static str, PackingConfig)> {
    vec![
        ("int4", PackingConfig::int4()),
        ("int8", PackingConfig::int8()),
        ("int8_tiled", PackingConfig::int8_tiled()),
        ("intn_fig9", PackingConfig::intn_fig9()),
        ("overpack_fig9", PackingConfig::overpack_fig9()),
        ("overpack_d1", PackingConfig::overpack_int4(-1).unwrap()),
        ("overpack_d2", PackingConfig::overpack_int4(-2).unwrap()),
        ("overpack_d3", PackingConfig::overpack_int4(-3).unwrap()),
        ("overpack6", PackingConfig::overpack6_int4()),
        ("precision6", PackingConfig::precision6()),
    ]
}

/// §V pinned exhaustively: over all 16·16·16·16 INT4 operand pairs, the
/// full round-half-up correction reproduces the exact scalar outer
/// product, and the uncorrected extraction shows the paper's error
/// structure (Table I row 1 / Table II row 1, EP and MAE within print
/// tolerance, WCE exactly 1, bias toward −∞).
#[test]
fn int4_exhaustive_differential() {
    let cfg = PackingConfig::int4();
    let full = PackedMultiplier::new(cfg.clone(), Correction::FullRoundHalfUp).unwrap();
    let raw = PackedMultiplier::new(cfg.clone(), Correction::None).unwrap();
    let mut raw_stats = vec![ErrorStats::default(); cfg.num_results()];
    let mut full_out = vec![0i128; cfg.num_results()];
    let mut raw_out = vec![0i128; cfg.num_results()];
    for a0 in 0i128..16 {
        for a1 in 0i128..16 {
            for w0 in -8i128..8 {
                for w1 in -8i128..8 {
                    let (a, w) = ([a0, a1], [w0, w1]);
                    let expected = cfg.expected(&a, &w);
                    full.multiply_unchecked_into(&a, &w, &mut full_out);
                    assert_eq!(
                        full_out, expected,
                        "full correction must be exact at a={a:?} w={w:?}"
                    );
                    raw.multiply_unchecked_into(&a, &w, &mut raw_out);
                    for (s, (&got, &exp)) in
                        raw_stats.iter_mut().zip(raw_out.iter().zip(&expected))
                    {
                        s.record(got, exp);
                    }
                }
            }
        }
    }
    // Table II row 1: per-result EP 0 / 46.87 / 49.80 / 52.73 %, WCE ≤ 1.
    let paper_ep = [0.0, 46.875, 49.805, 52.734];
    for (i, (s, ep)) in raw_stats.iter().zip(paper_ep).enumerate() {
        assert_eq!(s.n, 65536);
        assert!((s.ep_percent() - ep).abs() < 0.01, "r{i}: EP {}", s.ep_percent());
        assert!((s.mae() - ep / 100.0).abs() < 0.001, "r{i}: MAE {}", s.mae());
        assert!(s.wce <= 1, "r{i}: WCE {}", s.wce);
        if i > 0 {
            assert!(s.bias() < 0.0, "floor error biases toward -inf");
        }
    }
    // Table I row 1 aggregates: MAE-bar 0.37, EP-bar 37.35 %, WCE-bar 1.
    let mae_bar = raw_stats.iter().map(ErrorStats::mae).sum::<f64>() / 4.0;
    let ep_bar = raw_stats.iter().map(ErrorStats::ep_percent).sum::<f64>() / 4.0;
    let wce_bar = raw_stats.iter().map(|s| s.wce).max().unwrap();
    assert!((mae_bar - 0.37354).abs() < 0.0001, "MAE-bar {mae_bar}");
    assert!((ep_bar - 37.35).abs() < 0.01, "EP-bar {ep_bar}");
    assert_eq!(wce_bar, 1);
}

/// Codec roundtrip over randomized generated configurations: packed
/// operand words decode back to the exact operand vectors, on both the
/// unsigned `a` side and the sign-extended `w` side.
#[test]
fn prop_codec_roundtrip_randomized_configs() {
    let mut rng = Rng::new(0xC0DEC);
    let mut tested = 0;
    while tested < 300 {
        let n_a = rng.range_i64(1, 4) as usize;
        let n_w = rng.range_i64(1, 3) as usize;
        let a_width = rng.range_i64(2, 6) as u32;
        let w_width = rng.range_i64(2, 6) as u32;
        let delta = rng.range_i64(-3, 4) as i32;
        if (a_width + w_width) as i32 + delta <= 0 {
            continue;
        }
        let Ok(cfg) = PackingConfig::generate("rt", n_a, a_width, n_w, w_width, delta) else {
            continue; // overlapping operand fields — rejected by design
        };
        let packer = Packer::new(cfg);
        for _ in 0..20 {
            let a: Vec<i128> = packer
                .config()
                .a
                .iter()
                .map(|s| rng.range_i128(s.range().0, s.range().1))
                .collect();
            let w: Vec<i128> = packer
                .config()
                .w
                .iter()
                .map(|s| rng.range_i128(s.range().0, s.range().1))
                .collect();
            let word_a = packer.pack_a(&a).unwrap();
            assert_eq!(packer.unpack_a(word_a), a, "a roundtrip");
            let word_w = packer.pack_w_value_unchecked(&w);
            assert_eq!(packer.unpack_w_value(word_w), w, "w roundtrip");
        }
        tested += 1;
    }
}

/// Whole-matrix roundtrip: a plan decodes back to the weight matrix it
/// was built from, for strict and logical engines alike.
#[test]
fn prop_plan_decode_roundtrip() {
    let engines = [
        GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap(),
        GemmEngine::new(PackingConfig::int8(), Correction::None).unwrap(),
        GemmEngine::logical(PackingConfig::overpack6_int4(), Correction::MrRestore).unwrap(),
    ];
    let mut rng = Rng::new(0xDEC0DE);
    for eng in &engines {
        let (w_lo, w_hi) = eng.config().w[0].range();
        for _ in 0..10 {
            let k = 1 + rng.below(20) as usize;
            let n = 1 + rng.below(12) as usize;
            let w = MatI32::random_range(k, n, w_lo as i32, w_hi as i32, &mut rng);
            assert_eq!(eng.plan(&w).unwrap().decode(), w, "{}", eng.config().name);
        }
    }
}

/// Every preset configuration × correction mode that constructs (strict
/// first, falling back to the architecture-independent mode) must satisfy
/// `execute(plan(W), X) == matmul(X, W)` bit for bit — outputs and DSP
/// counters — on random matrices; the schemes that are exact must also
/// equal the exact i32 reference.
#[test]
fn prop_plan_execute_matmul_differential() {
    let presets = presets();
    // The schemes with an exactness guarantee to enforce: full correction
    // on δ ≥ 0 (§V-A), and the C-port correction on the two Xilinx
    // configurations (measured exhaustive, see EXPERIMENTS notes).
    let exact = |name: &str, corr: Correction, delta: i32| match corr {
        Correction::FullRoundHalfUp => delta >= 0,
        Correction::ApproxCPort => matches!(name, "int4" | "int8"),
        _ => false,
    };
    let mut rng = Rng::new(0xD1FF);
    let mut combos = 0;
    for &(name, ref cfg) in &presets {
        for corr in Correction::ALL {
            let engine = match GemmEngine::new(cfg.clone(), corr) {
                Ok(e) => e,
                Err(_) => match GemmEngine::logical(cfg.clone(), corr) {
                    Ok(e) => e,
                    Err(_) => continue, // invalid combination (e.g. MR on δ ≥ 0)
                },
            };
            combos += 1;
            let (a_lo, a_hi) = engine.config().a[0].range();
            let (w_lo, w_hi) = engine.config().w[0].range();
            for _ in 0..3 {
                let m = 1 + rng.below(9) as usize;
                let k = 1 + rng.below(24) as usize;
                let n = 1 + rng.below(9) as usize;
                let a = MatI32::random_range(m, k, a_lo as i32, a_hi as i32, &mut rng);
                let w = MatI32::random_range(k, n, w_lo as i32, w_hi as i32, &mut rng);
                let plan = engine.plan(&w).unwrap();
                let (via_plan, plan_stats) = engine.execute(&plan, &a).unwrap();
                let (one_shot, shot_stats) = engine.matmul(&a, &w).unwrap();
                assert_eq!(via_plan, one_shot, "{name}+{corr:?} {m}x{k}x{n}");
                assert_eq!(plan_stats, shot_stats, "{name}+{corr:?} {m}x{k}x{n}");
                if exact(name, corr, engine.config().delta) {
                    assert_eq!(
                        via_plan,
                        a.matmul_exact(&w).unwrap(),
                        "{name}+{corr:?} {m}x{k}x{n} must be exact"
                    );
                }
            }
        }
    }
    // 9 presets × 6 schemes minus the invalid combinations; make sure the
    // loop actually exercised a healthy cross-section.
    assert!(combos >= 30, "only {combos} engine combinations constructed");
}

/// **Kernel A/B pin** (blocked-vs-unblocked and unrolled-vs-scalar
/// bit-identity): for every preset configuration × correction scheme
/// that constructs — strict engines *and* the Fig. 9 logical sweeps,
/// which the preset list includes — the default
/// [`KernelMode::Blocked`] engine (cache-blocked block-column schedule,
/// 4-wide unrolled kernels, batch-resident activation planes) must be
/// **bit-identical** to the scalar [`KernelMode::Reference`] path (the
/// PR-3 shape): outputs AND `DspOpStats`, through shared plans and
/// through `matmul`. A 1-byte stripe budget forces `col_block = 1`, so
/// the genuinely multi-block schedule is exercised even on small
/// shapes.
#[test]
fn prop_blocked_unrolled_kernels_match_scalar_reference() {
    let mut rng = Rng::new(0xB10C);
    let mut combos = 0;
    for (name, cfg) in presets() {
        for corr in Correction::ALL {
            let engine = match GemmEngine::new(cfg.clone(), corr) {
                Ok(e) => e,
                Err(_) => match GemmEngine::logical(cfg.clone(), corr) {
                    Ok(e) => e,
                    Err(_) => continue, // invalid combination
                },
            };
            combos += 1;
            assert_eq!(engine.kernel_mode(), KernelMode::Blocked, "blocked is the default");
            let reference = engine.clone().with_kernel_mode(KernelMode::Reference);
            let tiny = engine.clone().with_stripe_budget(1);
            let (a_lo, a_hi) = engine.config().a[0].range();
            let (w_lo, w_hi) = engine.config().w[0].range();
            for _ in 0..3 {
                let m = 1 + rng.below(12) as usize;
                let k = 1 + rng.below(40) as usize;
                let n = 1 + rng.below(12) as usize;
                let a = MatI32::random_range(m, k, a_lo as i32, a_hi as i32, &mut rng);
                let w = MatI32::random_range(k, n, w_lo as i32, w_hi as i32, &mut rng);

                // Plans are kernel-agnostic: one plan serves both modes.
                let plan = engine.plan(&w).unwrap();
                let plan_tiny = tiny.plan(&w).unwrap();
                assert_eq!(plan_tiny.plan().col_block, 1, "{name}+{corr:?}");
                assert!(plan_tiny.plan().col_block <= plan.plan().col_block);

                let (cb, sb) = engine.execute(&plan, &a).unwrap();
                let (cr, sr) = reference.execute(&plan, &a).unwrap();
                assert_eq!(cb, cr, "{name}+{corr:?} {m}x{k}x{n} blocked vs reference");
                assert_eq!(sb, sr, "{name}+{corr:?} {m}x{k}x{n} DspOpStats");

                let (ct, st) = tiny.execute(&plan_tiny, &a).unwrap();
                assert_eq!(ct, cb, "{name}+{corr:?} {m}x{k}x{n} multi-block schedule");
                assert_eq!(st, sb, "{name}+{corr:?} {m}x{k}x{n} multi-block DspOpStats");

                // The matmul entry point agrees across kernel modes too.
                let (mb, smb) = engine.matmul(&a, &w).unwrap();
                let (mr, smr) = reference.matmul(&a, &w).unwrap();
                assert_eq!(mb, cb, "{name}+{corr:?} blocked matmul == execute");
                assert_eq!(mr, cb, "{name}+{corr:?} reference matmul == blocked");
                assert_eq!(smb, smr);
            }
        }
    }
    assert!(combos >= 30, "kernel A/B coverage regressed: {combos} combos");
}

/// **Narrow/wide backend differential** (the i64 datapath acceptance):
/// for every preset configuration × correction scheme that runs strict,
/// the auto-selected engine and the forced-wide engine must agree **bit
/// for bit** — outputs AND `DspOpStats` — over randomized shapes, both
/// through `matmul` and through cross-built plans. Narrow plans must be
/// rejected by wide engines and vice versa.
#[test]
fn prop_narrow_wide_backend_differential() {
    let mut rng = Rng::new(0x64128);
    let mut narrow_combos = 0;
    for (name, cfg) in presets() {
        for corr in Correction::ALL {
            let Ok(auto) = GemmEngine::new(cfg.clone(), corr) else {
                continue; // logical-only or invalid combination
            };
            if auto.word_backend() != WordBackend::Narrow64 {
                continue; // nothing to differentiate
            }
            narrow_combos += 1;
            let wide = GemmEngine::new_wide(cfg.clone(), corr).unwrap();
            assert_eq!(wide.word_backend(), WordBackend::Wide128);
            let (a_lo, a_hi) = auto.config().a[0].range();
            let (w_lo, w_hi) = auto.config().w[0].range();
            for _ in 0..4 {
                let m = 1 + rng.below(9) as usize;
                let k = 1 + rng.below(33) as usize;
                let n = 1 + rng.below(9) as usize;
                let a = MatI32::random_range(m, k, a_lo as i32, a_hi as i32, &mut rng);
                let w = MatI32::random_range(k, n, w_lo as i32, w_hi as i32, &mut rng);

                let plan_n = auto.plan(&w).unwrap();
                let plan_w = wide.plan(&w).unwrap();
                assert_eq!(plan_n.word_backend(), WordBackend::Narrow64);
                assert_eq!(plan_w.word_backend(), WordBackend::Wide128);
                // Planes carry identical weight information either way.
                assert_eq!(plan_n.decode(), plan_w.decode(), "{name}+{corr:?}");

                let (cn, sn) = auto.execute(&plan_n, &a).unwrap();
                let (cw, sw) = wide.execute(&plan_w, &a).unwrap();
                assert_eq!(cn, cw, "{name}+{corr:?} {m}x{k}x{n} outputs");
                assert_eq!(sn, sw, "{name}+{corr:?} {m}x{k}x{n} DspOpStats");

                let (mn, smn) = auto.matmul(&a, &w).unwrap();
                let (mw, smw) = wide.matmul(&a, &w).unwrap();
                assert_eq!(mn, cn, "{name}+{corr:?} narrow matmul == execute");
                assert_eq!(mw, cw, "{name}+{corr:?} wide matmul == execute");
                assert_eq!(smn, smw);

                // Plans are pinned to their backend.
                assert!(wide.execute(&plan_n, &a).is_err(), "narrow plan on wide engine");
                assert!(auto.execute(&plan_w, &a).is_err(), "wide plan on narrow engine");
            }
        }
    }
    // int4/int8 (4 non-MR schemes each) + the three overpack presets,
    // the row-tiled INT8 overpack and precision6 (6 schemes each): every
    // strict preset must have gone narrow.
    assert_eq!(narrow_combos, 38, "narrow coverage regressed");
}

/// **Exhaustive INT4 through the narrow engine**: drive every one of the
/// 16·16·16·16 INT4 operand combinations through the i64 datapath as
/// 2×1×2 GEMMs and re-derive the paper's error figures — the uncorrected
/// engine must reproduce the Table I/II row-1 statistics exactly, and
/// the round-half-up engine must be exact everywhere. This pins the §V
/// error structure to the *execution* path (drain-widened extraction
/// windows included), not just the scalar multiplier.
#[test]
fn int4_exhaustive_narrow_engine_matches_tables() {
    let raw = GemmEngine::new(PackingConfig::int4(), Correction::None).unwrap();
    let rhu = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    assert_eq!(raw.word_backend(), WordBackend::Narrow64);
    assert_eq!(rhu.word_backend(), WordBackend::Narrow64);
    // Result order by offset is a0w0, a1w0, a0w1, a1w1 → output cells
    // C[0][0], C[1][0], C[0][1], C[1][1].
    let cells = [(0usize, 0usize), (1, 0), (0, 1), (1, 1)];
    let mut stats = vec![ErrorStats::default(); 4];
    for w0 in -8i32..8 {
        for w1 in -8i32..8 {
            let w = MatI32::from_vec(1, 2, vec![w0, w1]).unwrap();
            let plan_raw = raw.plan(&w).unwrap();
            let plan_rhu = rhu.plan(&w).unwrap();
            for a0 in 0i32..16 {
                for a1 in 0i32..16 {
                    let a = MatI32::from_vec(2, 1, vec![a0, a1]).unwrap();
                    let (got_raw, _) = raw.execute(&plan_raw, &a).unwrap();
                    let (got_rhu, _) = rhu.execute(&plan_rhu, &a).unwrap();
                    let exact = a.matmul_exact(&w).unwrap();
                    assert_eq!(got_rhu, exact, "RHU exact at a=[{a0},{a1}] w=[{w0},{w1}]");
                    for (s, &(i, j)) in stats.iter_mut().zip(&cells) {
                        s.record(got_raw.get(i, j) as i128, exact.get(i, j) as i128);
                    }
                }
            }
        }
    }
    // Table II row 1 per-result figures, now measured through the narrow
    // engine: EP 0 / 46.87 / 49.80 / 52.73 %, WCE ≤ 1, floor bias.
    let paper_ep = [0.0, 46.875, 49.805, 52.734];
    for (i, (s, ep)) in stats.iter().zip(paper_ep).enumerate() {
        assert_eq!(s.n, 65536);
        assert!((s.ep_percent() - ep).abs() < 0.01, "r{i}: EP {}", s.ep_percent());
        assert!(s.wce <= 1, "r{i}: WCE {}", s.wce);
        if i > 0 {
            assert!(s.bias() < 0.0, "floor error biases toward -inf");
        }
    }
    let mae_bar = stats.iter().map(ErrorStats::mae).sum::<f64>() / 4.0;
    assert!((mae_bar - 0.37354).abs() < 0.0001, "MAE-bar {mae_bar}");
}

/// **Fig. 9 sweep outputs pinned before/after the narrow-logical
/// switch**: the architecture-independent Fig. 9 engines (INT-N δ=0,
/// Overpacking δ=−2, §IX overpack6) now auto-select the narrow (`i64`)
/// datapath; for every correction scheme that constructs, their outputs
/// AND `DspOpStats` must equal the pinned-wide logical engine
/// ([`GemmEngine::logical_wide`] — the pre-switch `i128` behaviour) bit
/// for bit, so the published sweep figures are unchanged by the
/// datapath swap. Cross-backend plans stay rejected in logical mode too.
#[test]
fn fig9_logical_sweeps_narrow_vs_wide_pinned() {
    let configs = [
        ("intn_fig9", PackingConfig::intn_fig9()),
        ("overpack_fig9", PackingConfig::overpack_fig9()),
        ("overpack6", PackingConfig::overpack6_int4()),
    ];
    let mut rng = Rng::new(0xF19);
    let mut combos = 0;
    for (name, cfg) in &configs {
        for corr in Correction::ALL {
            let Ok(narrow) = GemmEngine::logical(cfg.clone(), corr) else {
                continue; // invalid combination (e.g. MR on δ ≥ 0)
            };
            combos += 1;
            assert_eq!(
                narrow.word_backend(),
                WordBackend::Narrow64,
                "{name}+{corr:?}: logical engines on narrow configs must go narrow"
            );
            let wide = GemmEngine::logical_wide(cfg.clone(), corr).unwrap();
            assert_eq!(wide.word_backend(), WordBackend::Wide128);
            let (a_lo, a_hi) = cfg.a[0].range();
            let (w_lo, w_hi) = cfg.w[0].range();
            for _ in 0..4 {
                let m = 1 + rng.below(8) as usize;
                let k = 1 + rng.below(24) as usize;
                let n = 1 + rng.below(8) as usize;
                let a = MatI32::random_range(m, k, a_lo as i32, a_hi as i32, &mut rng);
                let w = MatI32::random_range(k, n, w_lo as i32, w_hi as i32, &mut rng);
                let plan_n = narrow.plan(&w).unwrap();
                let plan_w = wide.plan(&w).unwrap();
                assert_eq!(plan_n.word_backend(), WordBackend::Narrow64);
                assert_eq!(plan_w.word_backend(), WordBackend::Wide128);
                assert_eq!(plan_n.decode(), plan_w.decode(), "{name}+{corr:?}");
                let (cn, sn) = narrow.execute(&plan_n, &a).unwrap();
                let (cw, sw) = wide.execute(&plan_w, &a).unwrap();
                assert_eq!(cn, cw, "{name}+{corr:?} {m}x{k}x{n} sweep outputs");
                assert_eq!(sn, sw, "{name}+{corr:?} {m}x{k}x{n} DspOpStats");
                assert!(wide.execute(&plan_n, &a).is_err(), "narrow plan on wide engine");
                assert!(narrow.execute(&plan_w, &a).is_err(), "wide plan on narrow engine");
            }
        }
    }
    // INT-N δ=0 runs 4 schemes (no MR), both overpacked configs all 6.
    assert_eq!(combos, 16, "Fig. 9 logical coverage regressed");
}
