//! Coordinator concurrency conformance: many producers, one shared
//! weights-resident backend — every request answered exactly once, with
//! the class the exact reference assigns, at reproducible DSP cost.

use dsp_packing::coordinator::{
    BatcherConfig, Coordinator, InferenceBackend, PackedNnBackend, Request, ServerConfig,
};
use dsp_packing::correct::Correction;
use dsp_packing::gemm::GemmEngine;
use dsp_packing::nn::{data, ExecMode, QuantMlp};
use dsp_packing::packing::PackingConfig;
use std::sync::Arc;
use std::time::Duration;

fn packed_backend(ds: &data::Dataset) -> (Arc<PackedNnBackend>, Vec<usize>) {
    let mlp = QuantMlp::centroid_classifier(ds, 4, 4).unwrap();
    // The exact reference every served prediction must agree with (full
    // correction is bit-exact, so agreement is equality, not tolerance).
    let x = mlp.quantize_batch(&ds.images).unwrap();
    let (exact, _) = mlp.classify(&x, &ExecMode::Exact).unwrap();
    let engine = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    (Arc::new(PackedNnBackend::new(mlp, ExecMode::Packed(engine))), exact)
}

/// N producer threads hammer the batcher concurrently; every request gets
/// exactly one [`dsp_packing::coordinator::Prediction`], carrying the
/// same class the exact backend computes for that image.
#[test]
fn concurrent_producers_get_exactly_one_exact_class_each() {
    let ds = data::synthetic(96, 4, 64, 0.15, 7);
    let (backend, exact) = packed_backend(&ds);
    let coord = Coordinator::start(
        backend,
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 4096,
            },
            workers: 4,
            dsp_budget: 64,
        },
    );
    let handle = coord.handle();

    let n_producers = 8u64;
    let per_producer = 24u64;
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let handle = handle.clone();
        let images = ds.images.clone();
        let exact = exact.clone();
        producers.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..per_producer {
                let id = p * 1000 + i;
                let idx = ((p * per_producer + i) % images.len() as u64) as usize;
                let pred = handle
                    .infer(Request { id, image: images[idx].clone() })
                    .expect("serving must not drop well-formed requests");
                assert_eq!(pred.id, id, "response routed to its own request");
                assert_eq!(
                    pred.class, exact[idx],
                    "served class must equal the exact reference for image {idx}"
                );
                ids.push(id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for pr in producers {
        all_ids.extend(pr.join().unwrap());
    }
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(
        all_ids.len(),
        (n_producers * per_producer) as usize,
        "every request answered exactly once"
    );

    let m = coord.shutdown();
    assert_eq!(m.completed, n_producers * per_producer);
    assert_eq!(m.rejected, 0);
    assert!(m.dsp_utilization > 3.9, "int4 serves 4 mults per DSP cycle");
}

/// A request's reply channel delivers exactly one prediction — after it,
/// the channel is closed, not re-sent.
#[test]
fn reply_channel_carries_exactly_one_prediction() {
    let ds = data::synthetic(16, 4, 64, 0.15, 7);
    let (backend, _) = packed_backend(&ds);
    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();
    let rx = handle.submit(Request { id: 9, image: ds.images[0].clone() }).unwrap();
    let first = rx.recv().expect("one prediction arrives");
    assert_eq!(first.id, 9);
    assert!(rx.recv().is_err(), "no second prediction on the same channel");
    coord.shutdown();
}

/// Planned-weight reuse keeps the DSP work of identical batches
/// identical: the backend serves every batch from the same resident
/// [`dsp_packing::gemm::PackedWeights`], so repeated inference over the
/// same images consumes exactly the same `dsp_cycles` (no per-call
/// re-planning, no drift).
#[test]
fn repeated_identical_batches_consume_identical_dsp_cycles() {
    let ds = data::synthetic(32, 4, 64, 0.15, 11);
    let (backend, exact) = packed_backend(&ds);
    let (classes_1, stats_1) = backend.infer(&ds.images).unwrap();
    let (classes_2, stats_2) = backend.infer(&ds.images).unwrap();
    let (classes_3, stats_3) = backend.infer(&ds.images).unwrap();
    assert_eq!(classes_1, exact);
    assert_eq!(classes_1, classes_2);
    assert_eq!(classes_2, classes_3);
    assert_eq!(stats_1.dsp_cycles, stats_2.dsp_cycles, "resident plans: no cost drift");
    assert_eq!(stats_2.dsp_cycles, stats_3.dsp_cycles);
    assert_eq!(stats_1, stats_2, "all DSP counters identical, not just cycles");
    assert_eq!(stats_2, stats_3);
}
