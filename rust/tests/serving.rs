//! Coordinator concurrency + fault-tolerance conformance: many producers,
//! one shared weights-resident backend — every request answered exactly
//! once with a typed [`Outcome`], with the class the exact reference
//! assigns, at reproducible DSP cost. Covers the plain packed backend
//! (MLP), the adaptive precision-routing backend serving a deep CNN, and
//! the failure domains: poison-batch isolation, panic-safe workers with
//! supervisor respawn, deadline sweeps, admission shedding with retry,
//! and the seeded chaos soak over [`FaultInjectingBackend`].

use dsp_packing::coordinator::{
    AdaptiveBackend, AdmissionPolicy, BatcherConfig, BudgetChannelPolicy, Coordinator,
    FaultInjectingBackend, FaultSpec, GovernorConfig, GovernorState, InferenceBackend,
    InjectedFault, Outcome, PackedNnBackend, PrecisionClass, PrecisionPolicy, Request,
    RetryPolicy, RoutingGovernor, ServerConfig, ShedReason,
};
use dsp_packing::correct::Correction;
use dsp_packing::gemm::{DspOpStats, GemmEngine};
use dsp_packing::nn::{data, ExecMode, NnModel, QuantCnn, QuantMlp, StageSpec};
use dsp_packing::packing::PackingConfig;
use dsp_packing::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, Once};
use std::time::{Duration, Instant};

fn packed_backend(ds: &data::Dataset) -> (Arc<PackedNnBackend>, Vec<usize>) {
    let mlp = QuantMlp::centroid_classifier(ds, 4, 4).unwrap();
    // The exact reference every served prediction must agree with (full
    // correction is bit-exact, so agreement is equality, not tolerance).
    let x = mlp.quantize_batch(&ds.images).unwrap();
    let (exact, _) = mlp.classify(&x, &ExecMode::Exact).unwrap();
    let engine = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    (Arc::new(PackedNnBackend::new(mlp, ExecMode::Packed(engine))), exact)
}

/// Silence the stack traces of panics this suite *injects on purpose*
/// (fault injection + the marker panic backend); every other panic still
/// reaches the default hook. Installed once, process-wide.
fn quiet_injected_panics() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| info.payload().downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !(msg.contains("injected panic") || msg.contains("marker panic")) {
                prev(info);
            }
        }));
    });
}

/// N producer threads hammer the batcher concurrently; every request gets
/// exactly one [`dsp_packing::coordinator::Response`], carrying the same
/// class the exact backend computes for that image.
#[test]
fn concurrent_producers_get_exactly_one_exact_class_each() {
    let ds = data::synthetic(96, 4, 64, 0.15, 7);
    let (backend, exact) = packed_backend(&ds);
    let coord = Coordinator::start(
        backend,
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 4096,
            },
            workers: 4,
            dsp_budget: 64,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();

    let n_producers = 8u64;
    let per_producer = 24u64;
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let handle = handle.clone();
        let images = ds.images.clone();
        let exact = exact.clone();
        producers.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..per_producer {
                let id = p * 1000 + i;
                let idx = ((p * per_producer + i) % images.len() as u64) as usize;
                let pred = handle
                    .infer(Request::new(id, images[idx].clone()))
                    .expect("serving must not drop well-formed requests");
                assert_eq!(pred.id, id, "response routed to its own request");
                assert_eq!(
                    pred.class(),
                    Some(exact[idx]),
                    "served class must equal the exact reference for image {idx}"
                );
                ids.push(id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for pr in producers {
        all_ids.extend(pr.join().unwrap());
    }
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(
        all_ids.len(),
        (n_producers * per_producer) as usize,
        "every request answered exactly once"
    );

    let m = coord.shutdown();
    assert_eq!(m.completed, n_producers * per_producer);
    assert_eq!(m.rejected, 0);
    assert!(m.dsp_utilization > 3.9, "int4 serves 4 mults per DSP cycle");
}

/// A request's reply channel delivers exactly one response — after it,
/// the channel is closed, not re-sent.
#[test]
fn reply_channel_carries_exactly_one_response() {
    let ds = data::synthetic(16, 4, 64, 0.15, 7);
    let (backend, _) = packed_backend(&ds);
    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();
    let rx = handle.submit(Request::new(9, ds.images[0].clone())).unwrap();
    let first = rx.recv().expect("one response arrives");
    assert_eq!(first.id, 9);
    assert!(first.outcome.is_ok());
    assert!(rx.recv().is_err(), "no second response on the same channel");
    coord.shutdown();
}

/// Planned-weight reuse keeps the DSP work of identical batches
/// identical: the backend serves every batch from the same resident
/// [`dsp_packing::gemm::PackedWeights`], so repeated inference over the
/// same images consumes exactly the same `dsp_cycles` (no per-call
/// re-planning, no drift).
#[test]
fn repeated_identical_batches_consume_identical_dsp_cycles() {
    let ds = data::synthetic(32, 4, 64, 0.15, 11);
    let (backend, exact) = packed_backend(&ds);
    let (classes_1, stats_1) = backend.infer(&ds.images).unwrap();
    let (classes_2, stats_2) = backend.infer(&ds.images).unwrap();
    let (classes_3, stats_3) = backend.infer(&ds.images).unwrap();
    assert_eq!(classes_1, exact);
    assert_eq!(classes_1, classes_2);
    assert_eq!(classes_2, classes_3);
    assert_eq!(stats_1.dsp_cycles, stats_2.dsp_cycles, "resident plans: no cost drift");
    assert_eq!(stats_2.dsp_cycles, stats_3.dsp_cycles);
    assert_eq!(stats_1, stats_2, "all DSP counters identical, not just cycles");
    assert_eq!(stats_2, stats_3);
}

// --- adaptive precision routing over the deep CNN ----------------------

/// A 3-conv-stage CNN behind the adaptive router: exact requests run the
/// INT4-corrected fabric, approximate requests the MR-Overpacking fabric,
/// with the error budget carried in an appended metadata channel.
fn adaptive_cnn_backend(ds: &data::Dataset) -> Arc<AdaptiveBackend<BudgetChannelPolicy, QuantCnn>> {
    let specs = [
        StageSpec::conv3x3(4).with_pool(2, 2).unwrap(),
        StageSpec::conv3x3(6),
        StageSpec::conv3x3(8).with_pool(2, 2).unwrap(),
    ];
    let cnn = QuantCnn::deep(ds, 1, &specs, 4, 4, 17).unwrap();
    let exact = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    let dense =
        GemmEngine::logical(PackingConfig::overpack6_int4(), Correction::MrRestore).unwrap();
    Arc::new(AdaptiveBackend::new(
        cnn,
        ExecMode::Packed(exact),
        ExecMode::Packed(dense),
        BudgetChannelPolicy { threshold: 0.5 },
        true,
    ))
}

fn with_budget(img: &[f32], budget: f32) -> Vec<f32> {
    let mut v = img.to_vec();
    v.push(budget);
    v
}

/// N producers hammer the coordinator over the adaptive CNN backend:
/// every request is answered exactly once, and every request is routed
/// to exactly one fabric (the routing counters add up to the request
/// count, split deterministically by the budget channel).
#[test]
fn adaptive_cnn_concurrent_producers_exactly_once() {
    let ds = data::synthetic(64, 3, 64, 0.12, 19);
    let backend = adaptive_cnn_backend(&ds);
    assert_eq!(backend.name(), "cnn:adaptive");
    let coord = Coordinator::start(
        backend.clone(),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 4096,
            },
            workers: 4,
            dsp_budget: 64,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    let n_producers = 6u64;
    let per_producer = 16u64;
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let handle = handle.clone();
        let images = ds.images.clone();
        producers.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..per_producer {
                let global = p * per_producer + i;
                let idx = (global % images.len() as u64) as usize;
                // Alternate the error budget so both fabrics stay busy.
                let img = with_budget(&images[idx], (global % 2) as f32);
                let pred = handle
                    .infer(Request::new(global, img))
                    .expect("adaptive serving must not drop well-formed requests");
                assert_eq!(pred.id, global, "response routed to its own request");
                assert!(pred.outcome.is_ok());
                ids.push(pred.id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for pr in producers {
        all_ids.extend(pr.join().unwrap());
    }
    all_ids.sort_unstable();
    all_ids.dedup();
    let total = n_producers * per_producer;
    assert_eq!(all_ids.len(), total as usize, "every request answered exactly once");

    let m = coord.shutdown();
    assert_eq!(m.completed, total);
    assert_eq!(m.rejected, 0);
    // Exactly-once routing: the fabric counters partition the requests.
    let exact_n = backend.exact_routed.load(Ordering::Relaxed);
    let dense_n = backend.dense_routed.load(Ordering::Relaxed);
    assert_eq!(exact_n + dense_n, total);
    assert_eq!(exact_n, total / 2, "even budgets route exact");
    assert_eq!(dense_n, total / 2, "odd budgets route dense");
}

/// With all-exact budgets, the adaptive backend's served classes are
/// **bit-identical** to the exact reference — the INT4 + full-correction
/// fabric reproduces exact logits, so agreement is equality, not
/// tolerance, through all three conv stages and the head.
#[test]
fn adaptive_cnn_exact_route_is_bit_identical_to_exact_backend() {
    let ds = data::synthetic(32, 3, 64, 0.12, 23);
    let backend = adaptive_cnn_backend(&ds);
    let batch: Vec<Vec<f32>> = ds.images.iter().map(|img| with_budget(img, 0.0)).collect();
    let (preds, stats) = backend.infer(&batch).unwrap();
    let (exact_preds, _) = backend
        .exact_model()
        .classify_images(&ds.images, &ExecMode::Exact)
        .unwrap();
    assert_eq!(preds, exact_preds, "packed classes equal exact classes bit for bit");
    assert_eq!(backend.dense_routed.load(Ordering::Relaxed), 0);
    assert!((stats.utilization() - 4.0).abs() < 0.01, "pure int4 fabric: 4 mults/cycle");
}

/// Precision-class boundary cases: the threshold itself stays exact
/// (routing is strictly-greater), budgets just above it go dense, and a
/// missing budget channel defaults to exact.
#[test]
fn precision_class_boundary_cases() {
    let policy = BudgetChannelPolicy { threshold: 0.5 };
    assert_eq!(policy.classify(&[0.3, 0.5]), PrecisionClass::Exact);
    assert_eq!(policy.classify(&[0.3, 0.5001]), PrecisionClass::Approximate);
    assert_eq!(policy.classify(&[0.3, -1.0]), PrecisionClass::Exact);
    assert_eq!(policy.classify(&[]), PrecisionClass::Exact, "no channel defaults exact");

    // Through the backend: a batch pinned exactly at the threshold is
    // all-exact; epsilon above is all-dense.
    let ds = data::synthetic(8, 3, 64, 0.12, 41);
    let backend = adaptive_cnn_backend(&ds);
    let at: Vec<Vec<f32>> = ds.images.iter().map(|img| with_budget(img, 0.5)).collect();
    backend.infer(&at).unwrap();
    assert_eq!(backend.dense_routed.load(Ordering::Relaxed), 0);
    assert_eq!(backend.exact_routed.load(Ordering::Relaxed), 8);
    let above: Vec<Vec<f32>> = ds.images.iter().map(|img| with_budget(img, 0.6)).collect();
    backend.infer(&above).unwrap();
    assert_eq!(backend.dense_routed.load(Ordering::Relaxed), 8);
}

/// Repeated identical adaptive batches consume identical DSP work: both
/// fabric replicas serve resident plans, so `dsp_cycles` (and every
/// other counter) is deterministic across runs, with mixed utilization
/// between the two fabrics' densities.
#[test]
fn adaptive_cnn_dsp_cycles_reproducible() {
    let ds = data::synthetic(24, 3, 64, 0.12, 29);
    let backend = adaptive_cnn_backend(&ds);
    let batch: Vec<Vec<f32>> = ds
        .images
        .iter()
        .enumerate()
        .map(|(i, img)| with_budget(img, (i % 2) as f32))
        .collect();
    let (p1, s1) = backend.infer(&batch).unwrap();
    let (p2, s2) = backend.infer(&batch).unwrap();
    let (p3, s3) = backend.infer(&batch).unwrap();
    assert_eq!(p1, p2);
    assert_eq!(p2, p3);
    assert_eq!(s1.dsp_cycles, s2.dsp_cycles, "resident plans: no DSP-cost drift");
    assert_eq!(s1, s2, "all counters identical, not just cycles");
    assert_eq!(s2, s3);
    // Mixed routing: utilization sits between int4 (4) and overpack6 (6).
    assert!(s1.utilization() > 4.0 && s1.utilization() < 6.0, "{}", s1.utilization());
}

// --- failure domains ---------------------------------------------------

/// A backend whose `infer` blocks until the test opens the gate — the
/// deterministic way to hold requests in flight / in queue while gauges
/// and shedding are asserted.
struct Gate {
    opened: Mutex<bool>,
    cv: Condvar,
    entered: Mutex<usize>,
    entered_cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate {
            opened: Mutex::new(false),
            cv: Condvar::new(),
            entered: Mutex::new(0),
            entered_cv: Condvar::new(),
        })
    }

    fn release(&self) {
        *self.opened.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Block until `n` backend executions have started.
    fn wait_entered(&self, n: usize) {
        let mut e = self.entered.lock().unwrap();
        while *e < n {
            e = self.entered_cv.wait(e).unwrap();
        }
    }
}

struct GatedBackend {
    gate: Arc<Gate>,
}

impl InferenceBackend for GatedBackend {
    fn infer(&self, batch: &[Vec<f32>]) -> Result<(Vec<usize>, DspOpStats)> {
        {
            let mut e = self.gate.entered.lock().unwrap();
            *e += 1;
            self.gate.entered_cv.notify_all();
        }
        let mut opened = self.gate.opened.lock().unwrap();
        while !*opened {
            opened = self.gate.cv.wait(opened).unwrap();
        }
        Ok((vec![0; batch.len()], DspOpStats::default()))
    }

    fn name(&self) -> &str {
        "gated"
    }
}

/// A deterministic backend with a *content-marked* poison: the class is
/// a pure function of the image (`image[0] * 100`), so healthy results
/// never depend on batch composition, and any image whose second element
/// is exactly `1.0` poisons the batch it rides in (error or panic).
struct MarkerBackend {
    panic_on_marker: bool,
}

impl MarkerBackend {
    fn is_marker(img: &[f32]) -> bool {
        img.get(1).copied() == Some(1.0)
    }

    fn class_of(img: &[f32]) -> usize {
        (img[0] * 100.0).round() as usize
    }

    fn marked(class: usize, marker: bool) -> Vec<f32> {
        vec![class as f32 / 100.0, if marker { 1.0 } else { 0.0 }]
    }
}

impl InferenceBackend for MarkerBackend {
    fn infer(&self, batch: &[Vec<f32>]) -> Result<(Vec<usize>, DspOpStats)> {
        if batch.iter().any(|img| Self::is_marker(img)) {
            if self.panic_on_marker {
                panic!("marker panic");
            }
            return Err(Error::Runtime("marker poison in batch".into()));
        }
        Ok((batch.iter().map(|img| Self::class_of(img)).collect(), DspOpStats::default()))
    }

    fn name(&self) -> &str {
        "marker"
    }
}

/// The queue-depth and inflight gauges surface in the coordinator's
/// metrics snapshot while requests are actually queued / in flight, and
/// both return to zero once everything is answered.
#[test]
fn queue_depth_and_inflight_gauges_in_snapshot() {
    let gate = Gate::new();
    let coord = Coordinator::start(
        Arc::new(GatedBackend { gate: gate.clone() }),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(100),
                queue_cap: 64,
            },
            workers: 1,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    let rxs: Vec<_> =
        (0..3).map(|id| handle.submit(Request::new(id, vec![0.0, 0.0])).unwrap()).collect();
    gate.wait_entered(1);
    // One request in flight on the single worker (max_batch=1), the other
    // two still queued.
    let m = coord.metrics();
    assert_eq!(m.inflight, 1, "one popped batch in flight");
    assert_eq!(m.queue_depth, 2, "the rest still queued");
    assert_eq!(m.workers_alive, 1);
    gate.release();
    for rx in rxs {
        assert!(rx.recv().unwrap().outcome.is_ok());
    }
    let m = coord.metrics();
    assert_eq!(m.inflight, 0, "gauge returns to zero");
    assert_eq!(m.queue_depth, 0);
    assert_eq!(m.completed, 3);
    coord.shutdown();
}

/// Poison isolation: one poison request inside a batch of 8 gets
/// `Failed`, its seven healthy batchmates get classes **bit-identical**
/// to a fault-free run, and the bisection pins exactly one poison.
#[test]
fn poison_request_isolated_healthy_batchmates_unaffected() {
    let coord = Coordinator::start(
        Arc::new(MarkerBackend { panic_on_marker: false }),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(50),
                queue_cap: 64,
            },
            workers: 1,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    let rxs: Vec<_> = (0..8u64)
        .map(|id| {
            let img = MarkerBackend::marked(id as usize, id == 3);
            handle.submit(Request::new(id, img)).unwrap()
        })
        .collect();
    for (id, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, id as u64);
        if id == 3 {
            match resp.outcome {
                Outcome::Failed(Error::Runtime(ref m)) => {
                    assert!(m.contains("marker poison"), "the real error is pinned: {m}")
                }
                ref o => panic!("poison request must fail, got {o:?}"),
            }
        } else {
            assert_eq!(
                resp.class(),
                Some(id),
                "healthy batchmate gets its fault-free class"
            );
        }
    }
    let m = coord.shutdown();
    assert_eq!(m.poison_isolated, 1, "bisection pinned exactly one poison");
    assert_eq!(m.completed, 7);
    assert_eq!(m.failed, 1);
    assert_eq!(m.worker_panics, 0, "error poison never unwinds");
}

/// Panic-safe workers: a backend panic is caught, the poison request is
/// answered `Failed` (message carries the panic), healthy batchmates
/// still get their classes, and the supervisor respawns the retired
/// worker so the pool returns to full strength and keeps serving.
#[test]
fn backend_panic_answered_and_worker_respawned() {
    quiet_injected_panics();
    let coord = Coordinator::start(
        Arc::new(MarkerBackend { panic_on_marker: true }),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(20),
                queue_cap: 64,
            },
            workers: 2,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    let rxs: Vec<_> = (0..4u64)
        .map(|id| {
            let img = MarkerBackend::marked(id as usize, id == 2);
            handle.submit(Request::new(id, img)).unwrap()
        })
        .collect();
    for (id, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        if id == 2 {
            match resp.outcome {
                Outcome::Failed(Error::Coordinator(ref m)) => {
                    assert!(m.contains("panicked"), "panic surfaced in the error: {m}")
                }
                ref o => panic!("panic poison must fail, got {o:?}"),
            }
        } else {
            assert_eq!(resp.class(), Some(id), "healthy batchmates answered despite panic");
        }
    }
    // The panicked worker retired; the supervisor must respawn it. Poll
    // until the pool is back at full strength (respawn is asynchronous).
    let deadline = Instant::now() + Duration::from_secs(5);
    while coord.metrics().workers_alive < 2 {
        assert!(Instant::now() < deadline, "supervisor must restore the pool");
        std::thread::yield_now();
    }
    // The pool still serves after the panic (capacity did not decay).
    for id in 10..30u64 {
        let resp = handle.infer(Request::new(id, MarkerBackend::marked(5, false))).unwrap();
        assert_eq!(resp.class(), Some(5));
    }
    let m = coord.shutdown();
    assert!(m.worker_panics >= 1, "the shield counted the panic");
    assert!(m.workers_respawned >= 1, "the supervisor respawned the worker");
    assert_eq!(m.failed, 1);
    assert_eq!(m.completed, 23);
}

/// Deadline sweep: a request whose deadline passes while queued is
/// answered `DeadlineExceeded` at batch formation — exactly once, without
/// spending DSP cycles — while requests with live deadlines execute.
#[test]
fn expired_deadline_swept_with_typed_outcome() {
    let coord = Coordinator::start(
        Arc::new(MarkerBackend { panic_on_marker: false }),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_cap: 64,
            },
            workers: 1,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    let expired = Request::new(0, MarkerBackend::marked(1, false))
        .with_deadline(Instant::now() - Duration::from_millis(5));
    let resp = handle.infer(expired).unwrap();
    assert_eq!(resp.outcome, Outcome::DeadlineExceeded);

    let live = Request::new(1, MarkerBackend::marked(2, false))
        .with_timeout(Duration::from_secs(60));
    let resp = handle.infer(live).unwrap();
    assert_eq!(resp.class(), Some(2), "live deadline executes normally");

    let m = coord.shutdown();
    assert_eq!(m.deadline_exceeded, 1);
    assert_eq!(m.completed, 1);
}

/// Shed + retry: with the worker gated and the queue full, every submit
/// sheds with a typed `Shed(QueueFull)` outcome; `infer_with_retry`
/// retries through the backoff and — once capacity frees up — lands the
/// request. Sheds that never clear are returned typed, not as errors.
#[test]
fn shed_outcomes_retry_until_capacity_returns() {
    let gate = Gate::new();
    let coord = Coordinator::start(
        Arc::new(GatedBackend { gate: gate.clone() }),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(100),
                queue_cap: 1,
            },
            workers: 1,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    // Occupy the worker and fill the 1-deep queue.
    let rx_a = handle.submit(Request::new(0, vec![0.0, 0.0])).unwrap();
    gate.wait_entered(1);
    let rx_b = handle.submit(Request::new(1, vec![0.0, 0.0])).unwrap();

    // Saturated: bounded retry exhausts and hands back the typed shed.
    let retry = RetryPolicy {
        max_attempts: 3,
        base_backoff: Duration::from_micros(50),
        max_backoff: Duration::from_micros(200),
        seed: 7,
    };
    let resp = handle.infer_with_retry(Request::new(2, vec![0.0, 0.0]), &retry).unwrap();
    assert_eq!(resp.outcome, Outcome::Shed(ShedReason::QueueFull));
    assert!(!resp.outcome.is_ok());

    // Capacity returns: the same retry policy now lands the request.
    gate.release();
    assert!(rx_a.recv().unwrap().outcome.is_ok());
    assert!(rx_b.recv().unwrap().outcome.is_ok());
    let resp = handle.infer_with_retry(Request::new(3, vec![0.0, 0.0]), &retry).unwrap();
    assert!(resp.outcome.is_ok(), "retry succeeds once the queue drains: {resp:?}");

    let m = coord.shutdown();
    assert_eq!(m.rejected, 3, "three shed attempts while saturated");
    assert_eq!(m.completed, 3);
}

/// Admission-policy shedding at the coordinator level: beyond
/// `shed_depth` the policy sheds with `Shed(QueueDepth)` *before* the
/// hard `queue_cap`, and hysteresis releases once the queue drains to
/// `resume_depth`.
#[test]
fn admission_policy_sheds_before_queue_cap() {
    let gate = Gate::new();
    let coord = Coordinator::start(
        Arc::new(GatedBackend { gate: gate.clone() }),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(100),
                queue_cap: 64,
            },
            workers: 1,
            admission: AdmissionPolicy::depth(3, 0),
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    // Occupy the worker, then fill the queue to the shed threshold.
    let mut rxs = vec![handle.submit(Request::new(0, vec![0.0, 0.0])).unwrap()];
    gate.wait_entered(1);
    for id in 1..4 {
        rxs.push(handle.submit(Request::new(id, vec![0.0, 0.0])).unwrap());
    }
    // Depth is 3 (ids 1..3 queued, id 0 in flight): the policy engages
    // well below queue_cap=64.
    let resp = handle.submit(Request::new(4, vec![0.0, 0.0])).unwrap().recv().unwrap();
    assert_eq!(resp.outcome, Outcome::Shed(ShedReason::QueueDepth));
    assert!(handle.shedding());

    // Drain fully; at resume_depth=0 the hysteresis releases.
    gate.release();
    for rx in rxs {
        assert!(rx.recv().unwrap().outcome.is_ok());
    }
    let resp = handle.infer(Request::new(5, vec![0.0, 0.0])).unwrap();
    assert!(resp.outcome.is_ok(), "admitted again after the queue drained");
    assert!(!handle.shedding());

    let m = coord.shutdown();
    assert_eq!(m.shed, 1, "the admission policy shed id 4");
    assert_eq!(m.rejected, 0, "the hard cap was never reached");
    assert_eq!(m.completed, 5);
}

/// A backend with a fixed per-batch service delay — the deterministic
/// way to push the rolling p99 over a latency threshold.
struct SlowBackend {
    delay: Duration,
}

impl InferenceBackend for SlowBackend {
    fn infer(&self, batch: &[Vec<f32>]) -> Result<(Vec<usize>, DspOpStats)> {
        std::thread::sleep(self.delay);
        Ok((vec![0; batch.len()], DspOpStats::default()))
    }

    fn name(&self) -> &str {
        "slow"
    }
}

/// Regression for the p99 shed lockout: a latency burst drives the
/// admission policy into p99 shedding; shed answers never record into
/// the rolling window, so without time-based sample expiry the frozen
/// p99 would stay above `resume_p99_us` and the coordinator would shed
/// forever. With expiry, admission resumes once the burst ends.
#[test]
fn p99_shedding_resumes_after_burst_ends() {
    let coord = Coordinator::start(
        Arc::new(SlowBackend { delay: Duration::from_millis(5) }),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(100),
                queue_cap: 64,
            },
            workers: 1,
            admission: AdmissionPolicy {
                shed_depth: usize::MAX,
                resume_depth: usize::MAX,
                shed_p99_us: 1_000,
                resume_p99_us: 1_000,
                sample_ttl: Duration::from_millis(100),
            },
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    // Burst: sequential infers each take ~5 ms end to end, pushing the
    // rolling p99 far above the 1 ms shed threshold.
    for id in 0..8 {
        assert!(handle.infer(Request::new(id, vec![0.0])).unwrap().outcome.is_ok());
    }
    let resp = handle.submit(Request::new(8, vec![0.0])).unwrap().recv().unwrap();
    assert_eq!(resp.outcome, Outcome::Shed(ShedReason::LatencyP99), "p99 threshold engages");
    assert!(handle.shedding());
    // Burst over: nothing records new samples (the shed above certainly
    // did not). Once the stale ones expire, admission must resume.
    std::thread::sleep(Duration::from_millis(150));
    let resp = handle.infer(Request::new(9, vec![0.0])).unwrap();
    assert!(resp.outcome.is_ok(), "admission resumed after the burst: {resp:?}");
    assert!(!handle.shedding());
    let m = coord.shutdown();
    assert_eq!(m.shed, 1, "id 8 shed during the burst");
    assert_eq!(m.completed, 9);
}

/// While the governor is degraded, tolerant traffic moves to the
/// overpacked fabric but `Exact`-class requests keep their bit-exactness
/// guarantee: their served classes equal a fault-free exact-mode run.
#[test]
fn governed_exact_requests_bit_identical_while_degraded() {
    let ds = data::synthetic(32, 3, 64, 0.12, 31);
    let governor = Arc::new(RoutingGovernor::new(GovernorConfig::depth(8, 2)));
    let specs = [StageSpec::conv3x3(4).with_pool(2, 2).unwrap(), StageSpec::conv3x3(6)];
    let cnn = QuantCnn::deep(&ds, 1, &specs, 4, 4, 17).unwrap();
    let exact_engine =
        GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    let dense_engine =
        GemmEngine::logical(PackingConfig::overpack6_int4(), Correction::MrRestore).unwrap();
    let backend = AdaptiveBackend::new(
        cnn,
        ExecMode::Packed(exact_engine),
        ExecMode::Packed(dense_engine),
        BudgetChannelPolicy { threshold: 0.5 },
        true,
    )
    .with_governor(governor.clone());
    // Fault-free exact reference over the whole dataset.
    let (reference, _) =
        backend.exact_model().classify_images(&ds.images, &ExecMode::Exact).unwrap();
    // Queue pressure: the governor degrades tolerant routing.
    governor.signal().publish_depth(64);
    let batch: Vec<Vec<f32>> = ds
        .images
        .iter()
        .enumerate()
        .map(|(i, img)| with_budget(img, if i % 2 == 0 { 0.0 } else { 1.0 }))
        .collect();
    let (preds, _) = backend.infer(&batch).unwrap();
    assert!(governor.is_degraded(), "depth 64 engages at threshold 8");
    assert_eq!(backend.dense_routed.load(Ordering::Relaxed), 16, "tolerant half degraded");
    assert_eq!(governor.degraded_routed(), 16);
    for (i, (p, r)) in preds.iter().zip(&reference).enumerate() {
        if i % 2 == 0 {
            assert_eq!(p, r, "Exact-class request {i} bit-identical while degraded");
        }
    }
}

/// The coordinator publishes its load signal into an attached governor
/// and folds the governor's gauges into every metrics snapshot.
#[test]
fn governor_gauges_surface_in_coordinator_metrics() {
    let ds = data::synthetic(16, 4, 64, 0.15, 7);
    let (backend, _) = packed_backend(&ds);
    let governor = Arc::new(RoutingGovernor::new(GovernorConfig::depth(4, 0)));
    // Engage via a direct poll (the adaptive backend's job in a full
    // deployment) so the gauge fill path is what's under test.
    governor.signal().publish_depth(64);
    assert_eq!(governor.poll(), GovernorState::Degraded);
    governor.note_degraded_routed(3);
    let coord = Coordinator::start(
        backend,
        ServerConfig { governor: Some(governor.clone()), ..ServerConfig::default() },
    );
    let handle = coord.handle();
    for (i, img) in ds.images.iter().take(4).enumerate() {
        assert!(handle.infer(Request::new(i as u64, img.clone())).unwrap().outcome.is_ok());
    }
    assert!(governor.signal().answered() >= 4, "answers published into the shared signal");
    let m = coord.metrics();
    assert_eq!(m.governor_degraded, 1);
    assert_eq!(m.governor_engagements, 1);
    assert_eq!(m.degraded_routed, 3);
    let m = coord.shutdown();
    assert_eq!(m.degraded_routed, 3, "shutdown snapshot carries the gauges too");
}

// --- seeded chaos soak --------------------------------------------------

fn chaos_spec(default_mult: f64) -> FaultSpec {
    let seed = std::env::var("DSP_PACKING_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC4A0_5EED);
    let mult = std::env::var("DSP_PACKING_CHAOS_RATE_MULT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_mult);
    FaultSpec {
        seed,
        error_rate: 0.06,
        panic_rate: 0.05,
        delay_rate: 0.04,
        delay: Duration::from_micros(300),
    }
    .scaled(mult)
}

/// The chaos soak: a seeded [`FaultInjectingBackend`] wraps the packed
/// MLP and injects errors, panics and latency spikes while concurrent
/// clients stream requests. Invariants:
///
/// * exactly one typed outcome per request, zero hangs;
/// * healthy requests get classes **bit-identical** to the fault-free
///   run (fault assignment is per-request-content, so bisection shields
///   batchmates completely);
/// * poisoned requests get `Failed`, never a silent drop;
/// * the accounting identity holds (`answered == accepted`, no sheds);
/// * the worker pool is back at full strength at the end.
fn chaos_soak(n_clients: u64, per_client: u64, spec: FaultSpec) {
    quiet_injected_panics();
    eprintln!(
        "chaos soak: seed {:#x} (replay via DSP_PACKING_CHAOS_SEED), \
         rates err={:.3} panic={:.3} delay={:.3}",
        spec.seed, spec.error_rate, spec.panic_rate, spec.delay_rate
    );
    let ds = data::synthetic(96, 4, 64, 0.15, 7);
    let mlp = QuantMlp::centroid_classifier(&ds, 4, 4).unwrap();
    let engine = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    let inner = PackedNnBackend::new(mlp, ExecMode::Packed(engine));
    // Fault-free reference, computed before any injection exists.
    let reference = inner.infer(&ds.images).unwrap().0;
    let faulty = Arc::new(FaultInjectingBackend::new(inner, spec));
    // The fault set is a pure function of (seed, image): compute the
    // expected outcome of every request up front.
    let faults: Vec<Option<InjectedFault>> =
        ds.images.iter().map(|img| faulty.fault_for(img)).collect();
    let any_panic_poison = faults.iter().any(|f| *f == Some(InjectedFault::Panic));

    let workers = 3u64;
    let coord = Coordinator::start(
        faulty.clone(),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 65_536,
            },
            workers: workers as usize,
            ..ServerConfig::default()
        },
    );
    let handle = coord.handle();
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let handle = handle.clone();
        let images = ds.images.clone();
        let reference = reference.clone();
        let faults = faults.clone();
        clients.push(std::thread::spawn(move || {
            let mut poisoned = 0u64;
            for i in 0..per_client {
                let id = c * 1_000_000 + i;
                let idx = ((c * per_client + i) % images.len() as u64) as usize;
                let resp = handle
                    .infer(Request::new(id, images[idx].clone()))
                    .expect("chaos must never surface as a submit error");
                assert_eq!(resp.id, id, "exactly-once: response routed to its request");
                match faults[idx] {
                    None => assert_eq!(
                        resp.class(),
                        Some(reference[idx]),
                        "healthy request {idx} must be bit-identical to the fault-free run"
                    ),
                    Some(_) => {
                        poisoned += 1;
                        assert!(
                            matches!(resp.outcome, Outcome::Failed(_)),
                            "poisoned request {idx} must fail typed, got {:?}",
                            resp.outcome
                        );
                    }
                }
            }
            poisoned
        }));
    }
    let mut poisoned_total = 0u64;
    for cl in clients {
        poisoned_total += cl.join().unwrap();
    }

    // The pool must return to full strength (respawn is asynchronous).
    let deadline = Instant::now() + Duration::from_secs(10);
    while coord.metrics().workers_alive < workers {
        assert!(Instant::now() < deadline, "supervisor must restore the pool");
        std::thread::yield_now();
    }
    let total = n_clients * per_client;
    let m = coord.shutdown();
    assert_eq!(m.accepted, total, "nothing shed at these queue limits");
    assert_eq!(m.shed, 0);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.answered(), total, "exactly one typed outcome per request");
    assert_eq!(m.failed, poisoned_total);
    assert_eq!(m.completed, total - poisoned_total);
    if any_panic_poison {
        assert!(m.worker_panics >= 1, "panic poison must exercise the shield");
        assert!(m.workers_respawned >= 1, "every panicked worker is replaced");
    }
    eprintln!(
        "chaos soak: {} requests, {} poisoned, {} panics caught, {} respawns",
        total, poisoned_total, m.worker_panics, m.workers_respawned
    );
}

#[test]
fn chaos_soak_exactly_once_typed_outcomes() {
    chaos_soak(4, 64, chaos_spec(1.0));
}

/// The scheduled exhaustive variant: 10× injection rates (overridable via
/// `DSP_PACKING_CHAOS_RATE_MULT`), more clients, more traffic. Replay any
/// failure with the printed `DSP_PACKING_CHAOS_SEED`.
#[test]
#[ignore]
fn chaos_soak_exhaustive() {
    chaos_soak(8, 250, chaos_spec(10.0));
}
