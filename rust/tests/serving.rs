//! Coordinator concurrency conformance: many producers, one shared
//! weights-resident backend — every request answered exactly once, with
//! the class the exact reference assigns, at reproducible DSP cost.
//! Covers the plain packed backend (MLP) and the adaptive
//! precision-routing backend serving a deep CNN across two fabrics.

use dsp_packing::coordinator::{
    AdaptiveBackend, BatcherConfig, BudgetChannelPolicy, Coordinator, InferenceBackend,
    PackedNnBackend, PrecisionClass, PrecisionPolicy, Request, ServerConfig,
};
use dsp_packing::correct::Correction;
use dsp_packing::gemm::GemmEngine;
use dsp_packing::nn::{data, ExecMode, NnModel, QuantCnn, QuantMlp, StageSpec};
use dsp_packing::packing::PackingConfig;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn packed_backend(ds: &data::Dataset) -> (Arc<PackedNnBackend>, Vec<usize>) {
    let mlp = QuantMlp::centroid_classifier(ds, 4, 4).unwrap();
    // The exact reference every served prediction must agree with (full
    // correction is bit-exact, so agreement is equality, not tolerance).
    let x = mlp.quantize_batch(&ds.images).unwrap();
    let (exact, _) = mlp.classify(&x, &ExecMode::Exact).unwrap();
    let engine = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    (Arc::new(PackedNnBackend::new(mlp, ExecMode::Packed(engine))), exact)
}

/// N producer threads hammer the batcher concurrently; every request gets
/// exactly one [`dsp_packing::coordinator::Prediction`], carrying the
/// same class the exact backend computes for that image.
#[test]
fn concurrent_producers_get_exactly_one_exact_class_each() {
    let ds = data::synthetic(96, 4, 64, 0.15, 7);
    let (backend, exact) = packed_backend(&ds);
    let coord = Coordinator::start(
        backend,
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 4096,
            },
            workers: 4,
            dsp_budget: 64,
        },
    );
    let handle = coord.handle();

    let n_producers = 8u64;
    let per_producer = 24u64;
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let handle = handle.clone();
        let images = ds.images.clone();
        let exact = exact.clone();
        producers.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..per_producer {
                let id = p * 1000 + i;
                let idx = ((p * per_producer + i) % images.len() as u64) as usize;
                let pred = handle
                    .infer(Request { id, image: images[idx].clone() })
                    .expect("serving must not drop well-formed requests");
                assert_eq!(pred.id, id, "response routed to its own request");
                assert_eq!(
                    pred.class, exact[idx],
                    "served class must equal the exact reference for image {idx}"
                );
                ids.push(id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for pr in producers {
        all_ids.extend(pr.join().unwrap());
    }
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(
        all_ids.len(),
        (n_producers * per_producer) as usize,
        "every request answered exactly once"
    );

    let m = coord.shutdown();
    assert_eq!(m.completed, n_producers * per_producer);
    assert_eq!(m.rejected, 0);
    assert!(m.dsp_utilization > 3.9, "int4 serves 4 mults per DSP cycle");
}

/// A request's reply channel delivers exactly one prediction — after it,
/// the channel is closed, not re-sent.
#[test]
fn reply_channel_carries_exactly_one_prediction() {
    let ds = data::synthetic(16, 4, 64, 0.15, 7);
    let (backend, _) = packed_backend(&ds);
    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();
    let rx = handle.submit(Request { id: 9, image: ds.images[0].clone() }).unwrap();
    let first = rx.recv().expect("one prediction arrives");
    assert_eq!(first.id, 9);
    assert!(rx.recv().is_err(), "no second prediction on the same channel");
    coord.shutdown();
}

/// Planned-weight reuse keeps the DSP work of identical batches
/// identical: the backend serves every batch from the same resident
/// [`dsp_packing::gemm::PackedWeights`], so repeated inference over the
/// same images consumes exactly the same `dsp_cycles` (no per-call
/// re-planning, no drift).
#[test]
fn repeated_identical_batches_consume_identical_dsp_cycles() {
    let ds = data::synthetic(32, 4, 64, 0.15, 11);
    let (backend, exact) = packed_backend(&ds);
    let (classes_1, stats_1) = backend.infer(&ds.images).unwrap();
    let (classes_2, stats_2) = backend.infer(&ds.images).unwrap();
    let (classes_3, stats_3) = backend.infer(&ds.images).unwrap();
    assert_eq!(classes_1, exact);
    assert_eq!(classes_1, classes_2);
    assert_eq!(classes_2, classes_3);
    assert_eq!(stats_1.dsp_cycles, stats_2.dsp_cycles, "resident plans: no cost drift");
    assert_eq!(stats_2.dsp_cycles, stats_3.dsp_cycles);
    assert_eq!(stats_1, stats_2, "all DSP counters identical, not just cycles");
    assert_eq!(stats_2, stats_3);
}

// --- adaptive precision routing over the deep CNN ----------------------

/// A 3-conv-stage CNN behind the adaptive router: exact requests run the
/// INT4-corrected fabric, approximate requests the MR-Overpacking fabric,
/// with the error budget carried in an appended metadata channel.
fn adaptive_cnn_backend(ds: &data::Dataset) -> Arc<AdaptiveBackend<BudgetChannelPolicy, QuantCnn>> {
    let specs = [
        StageSpec::conv3x3(4).with_pool(2, 2).unwrap(),
        StageSpec::conv3x3(6),
        StageSpec::conv3x3(8).with_pool(2, 2).unwrap(),
    ];
    let cnn = QuantCnn::deep(ds, 1, &specs, 4, 4, 17).unwrap();
    let exact = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap();
    let dense =
        GemmEngine::logical(PackingConfig::overpack6_int4(), Correction::MrRestore).unwrap();
    Arc::new(AdaptiveBackend::new(
        cnn,
        ExecMode::Packed(exact),
        ExecMode::Packed(dense),
        BudgetChannelPolicy { threshold: 0.5 },
        true,
    ))
}

fn with_budget(img: &[f32], budget: f32) -> Vec<f32> {
    let mut v = img.to_vec();
    v.push(budget);
    v
}

/// N producers hammer the coordinator over the adaptive CNN backend:
/// every request is answered exactly once, and every request is routed
/// to exactly one fabric (the routing counters add up to the request
/// count, split deterministically by the budget channel).
#[test]
fn adaptive_cnn_concurrent_producers_exactly_once() {
    let ds = data::synthetic(64, 3, 64, 0.12, 19);
    let backend = adaptive_cnn_backend(&ds);
    assert_eq!(backend.name(), "cnn:adaptive");
    let coord = Coordinator::start(
        backend.clone(),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 4096,
            },
            workers: 4,
            dsp_budget: 64,
        },
    );
    let handle = coord.handle();
    let n_producers = 6u64;
    let per_producer = 16u64;
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let handle = handle.clone();
        let images = ds.images.clone();
        producers.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..per_producer {
                let global = p * per_producer + i;
                let idx = (global % images.len() as u64) as usize;
                // Alternate the error budget so both fabrics stay busy.
                let img = with_budget(&images[idx], (global % 2) as f32);
                let pred = handle
                    .infer(Request { id: global, image: img })
                    .expect("adaptive serving must not drop well-formed requests");
                assert_eq!(pred.id, global, "response routed to its own request");
                ids.push(pred.id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for pr in producers {
        all_ids.extend(pr.join().unwrap());
    }
    all_ids.sort_unstable();
    all_ids.dedup();
    let total = n_producers * per_producer;
    assert_eq!(all_ids.len(), total as usize, "every request answered exactly once");

    let m = coord.shutdown();
    assert_eq!(m.completed, total);
    assert_eq!(m.rejected, 0);
    // Exactly-once routing: the fabric counters partition the requests.
    let exact_n = backend.exact_routed.load(Ordering::Relaxed);
    let dense_n = backend.dense_routed.load(Ordering::Relaxed);
    assert_eq!(exact_n + dense_n, total);
    assert_eq!(exact_n, total / 2, "even budgets route exact");
    assert_eq!(dense_n, total / 2, "odd budgets route dense");
}

/// With all-exact budgets, the adaptive backend's served classes are
/// **bit-identical** to the exact reference — the INT4 + full-correction
/// fabric reproduces exact logits, so agreement is equality, not
/// tolerance, through all three conv stages and the head.
#[test]
fn adaptive_cnn_exact_route_is_bit_identical_to_exact_backend() {
    let ds = data::synthetic(32, 3, 64, 0.12, 23);
    let backend = adaptive_cnn_backend(&ds);
    let batch: Vec<Vec<f32>> = ds.images.iter().map(|img| with_budget(img, 0.0)).collect();
    let (preds, stats) = backend.infer(&batch).unwrap();
    let (exact_preds, _) = backend
        .exact_model()
        .classify_images(&ds.images, &ExecMode::Exact)
        .unwrap();
    assert_eq!(preds, exact_preds, "packed classes equal exact classes bit for bit");
    assert_eq!(backend.dense_routed.load(Ordering::Relaxed), 0);
    assert!((stats.utilization() - 4.0).abs() < 0.01, "pure int4 fabric: 4 mults/cycle");
}

/// Precision-class boundary cases: the threshold itself stays exact
/// (routing is strictly-greater), budgets just above it go dense, and a
/// missing budget channel defaults to exact.
#[test]
fn precision_class_boundary_cases() {
    let policy = BudgetChannelPolicy { threshold: 0.5 };
    assert_eq!(policy.classify(&[0.3, 0.5]), PrecisionClass::Exact);
    assert_eq!(policy.classify(&[0.3, 0.5001]), PrecisionClass::Approximate);
    assert_eq!(policy.classify(&[0.3, -1.0]), PrecisionClass::Exact);
    assert_eq!(policy.classify(&[]), PrecisionClass::Exact, "no channel defaults exact");

    // Through the backend: a batch pinned exactly at the threshold is
    // all-exact; epsilon above is all-dense.
    let ds = data::synthetic(8, 3, 64, 0.12, 41);
    let backend = adaptive_cnn_backend(&ds);
    let at: Vec<Vec<f32>> = ds.images.iter().map(|img| with_budget(img, 0.5)).collect();
    backend.infer(&at).unwrap();
    assert_eq!(backend.dense_routed.load(Ordering::Relaxed), 0);
    assert_eq!(backend.exact_routed.load(Ordering::Relaxed), 8);
    let above: Vec<Vec<f32>> = ds.images.iter().map(|img| with_budget(img, 0.6)).collect();
    backend.infer(&above).unwrap();
    assert_eq!(backend.dense_routed.load(Ordering::Relaxed), 8);
}

/// Repeated identical adaptive batches consume identical DSP work: both
/// fabric replicas serve resident plans, so `dsp_cycles` (and every
/// other counter) is deterministic across runs, with mixed utilization
/// between the two fabrics' densities.
#[test]
fn adaptive_cnn_dsp_cycles_reproducible() {
    let ds = data::synthetic(24, 3, 64, 0.12, 29);
    let backend = adaptive_cnn_backend(&ds);
    let batch: Vec<Vec<f32>> = ds
        .images
        .iter()
        .enumerate()
        .map(|(i, img)| with_budget(img, (i % 2) as f32))
        .collect();
    let (p1, s1) = backend.infer(&batch).unwrap();
    let (p2, s2) = backend.infer(&batch).unwrap();
    let (p3, s3) = backend.infer(&batch).unwrap();
    assert_eq!(p1, p2);
    assert_eq!(p2, p3);
    assert_eq!(s1.dsp_cycles, s2.dsp_cycles, "resident plans: no DSP-cost drift");
    assert_eq!(s1, s2, "all counters identical, not just cycles");
    assert_eq!(s2, s3);
    // Mixed routing: utilization sits between int4 (4) and overpack6 (6).
    assert!(s1.utilization() > 4.0 && s1.utilization() < 6.0, "{}", s1.utilization());
}
