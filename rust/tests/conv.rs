//! Differential conv battery: the im2col-lowered packed convolution
//! checked against an independent naive direct-convolution oracle.
//!
//! * the exhaustive small-shape sweep pins the exact path: over a grid of
//!   (channels, image, kernel, stride, padding) shapes, packed conv with
//!   full correction equals the naive i32 direct convolution bit for bit;
//! * every preset packing × correction mode is checked for planned-path
//!   bit-identity (layer forward == one-shot GEMM on the same patches,
//!   outputs and DSP counters), with the exact schemes also pinned to the
//!   oracle;
//! * im2col round-trips through col2im at the integration level;
//! * the conv plan cache rebuilds on weight mutation and engine swap;
//! * the coordinator serves the CNN backend end to end.

use dsp_packing::coordinator::{
    Coordinator, InferenceBackend, PackedNnBackend, Request, ServerConfig,
};
use dsp_packing::correct::Correction;
use dsp_packing::gemm::{DspOpStats, GemmEngine, Im2col, MatI32};
use dsp_packing::nn::{
    data, Conv2dLayer, ConvGeometry, ExecMode, NnModel, PlanBudget, QuantCnn, StageSpec,
};
use dsp_packing::packing::PackingConfig;
use dsp_packing::util::Rng;
use std::sync::Arc;

/// One conv problem shape: channels, image height/width, kernel, stride,
/// padding.
#[derive(Debug, Clone, Copy)]
struct Shape {
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    s: usize,
    p: usize,
}

impl Shape {
    fn out_dims(&self) -> (usize, usize) {
        (
            (self.h + 2 * self.p - self.k) / self.s + 1,
            (self.w + 2 * self.p - self.k) / self.s + 1,
        )
    }

    fn geometry(&self) -> ConvGeometry {
        ConvGeometry::new(self.c, self.k, self.s, self.p).unwrap()
    }

    fn spec(&self) -> Im2col {
        self.geometry().spec(self.h, self.w).unwrap()
    }
}

/// Naive direct convolution — the oracle. Deliberately independent of the
/// im2col path: explicit loops over output positions and kernel taps,
/// i64 accumulation, zero padding. Output layout matches
/// `Conv2dLayer::forward`: `(batch·OH·OW) × filters`.
fn direct_conv(x: &MatI32, weights: &MatI32, bias: &[i32], sh: Shape) -> MatI32 {
    let (oh, ow) = sh.out_dims();
    let mut out = MatI32::zeros(x.rows * oh * ow, weights.cols);
    for b in 0..x.rows {
        for oy in 0..oh {
            for ox in 0..ow {
                for f in 0..weights.cols {
                    let mut acc = 0i64;
                    for c in 0..sh.c {
                        for ky in 0..sh.k {
                            for kx in 0..sh.k {
                                let iy = (oy * sh.s + ky) as isize - sh.p as isize;
                                let ix = (ox * sh.s + kx) as isize - sh.p as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy >= sh.h as isize
                                    || ix >= sh.w as isize
                                {
                                    continue;
                                }
                                let xv = x.get(
                                    b,
                                    c * sh.h * sh.w + iy as usize * sh.w + ix as usize,
                                ) as i64;
                                let wv =
                                    weights.get(c * sh.k * sh.k + ky * sh.k + kx, f) as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    out.set(
                        b * oh * ow + oy * ow + ox,
                        f,
                        acc as i32 + bias[f],
                    );
                }
            }
        }
    }
    out
}

fn int4_engine() -> GemmEngine {
    GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp).unwrap()
}

/// Exhaustive small-shape differential: packed conv with full correction
/// (and the exact mode) equal the naive direct convolution on every shape
/// of the grid.
#[test]
fn exhaustive_small_shapes_match_direct_convolution() {
    let engine = int4_engine();
    let mut rng = Rng::new(0xC0); // NB: shared across shapes on purpose
    let mut checked = 0;
    for c in [1usize, 2] {
        for h in [3usize, 4, 5] {
            for w in [h, h + 1] {
                for k in [1usize, 2, 3] {
                    for s in [1usize, 2] {
                        for p in [0usize, 1] {
                            if h + 2 * p < k || w + 2 * p < k {
                                continue;
                            }
                            let sh = Shape { c, h, w, k, s, p };
                            let filters = 3;
                            let x = MatI32::random_range(2, c * h * w, 0, 15, &mut rng);
                            let wq = MatI32::random_range(
                                sh.geometry().patch_len(),
                                filters,
                                -8,
                                7,
                                &mut rng,
                            );
                            let bias: Vec<i32> =
                                (0..filters).map(|_| rng.range_i64(-20, 20) as i32).collect();
                            let conv =
                                Conv2dLayer::new(wq, bias.clone(), sh.geometry(), false).unwrap();
                            let oracle = direct_conv(&x, &conv.dense.weights, &bias, sh);

                            let mut stats = DspOpStats::default();
                            let exact = conv
                                .forward(&x, h, w, &ExecMode::Exact, 4, &mut stats)
                                .unwrap();
                            assert_eq!(exact, oracle, "exact path {sh:?}");

                            let mode = ExecMode::Packed(engine.clone());
                            let packed =
                                conv.forward(&x, h, w, &mode, 4, &mut stats).unwrap();
                            assert_eq!(packed, oracle, "packed path {sh:?}");
                            checked += 1;
                        }
                    }
                }
            }
        }
    }
    assert!(checked >= 100, "grid only produced {checked} shapes");
}

/// Every preset packing × correction mode that constructs: the planned
/// conv layer forward is bit-identical — outputs and DSP counters — to
/// the one-shot GEMM over the same im2col patches, and the schemes with
/// an exactness guarantee also equal the naive oracle.
#[test]
fn preset_config_sweep_is_plan_execute_identical() {
    let presets: Vec<(&str, PackingConfig)> = vec![
        ("int4", PackingConfig::int4()),
        ("int8", PackingConfig::int8()),
        ("int8_tiled", PackingConfig::int8_tiled()),
        ("intn_fig9", PackingConfig::intn_fig9()),
        ("overpack_fig9", PackingConfig::overpack_fig9()),
        ("overpack_d1", PackingConfig::overpack_int4(-1).unwrap()),
        ("overpack_d2", PackingConfig::overpack_int4(-2).unwrap()),
        ("overpack_d3", PackingConfig::overpack_int4(-3).unwrap()),
        ("overpack6", PackingConfig::overpack6_int4()),
        ("precision6", PackingConfig::precision6()),
    ];
    let exact = |name: &str, corr: Correction, delta: i32| match corr {
        Correction::FullRoundHalfUp => delta >= 0,
        Correction::ApproxCPort => matches!(name, "int4" | "int8"),
        _ => false,
    };
    let shapes = [
        Shape { c: 1, h: 4, w: 4, k: 3, s: 1, p: 0 },
        Shape { c: 2, h: 5, w: 4, k: 2, s: 2, p: 1 },
        Shape { c: 3, h: 4, w: 6, k: 3, s: 1, p: 1 },
    ];
    let mut rng = Rng::new(0xC0D1FF);
    let mut combos = 0;
    for &(name, ref cfg) in &presets {
        for corr in Correction::ALL {
            let engine = match GemmEngine::new(cfg.clone(), corr) {
                Ok(e) => e,
                Err(_) => match GemmEngine::logical(cfg.clone(), corr) {
                    Ok(e) => e,
                    Err(_) => continue, // invalid combination
                },
            };
            combos += 1;
            let (a_lo, a_hi) = engine.config().a[0].range();
            let (w_lo, w_hi) = engine.config().w[0].range();
            for sh in shapes {
                let x = MatI32::random_range(
                    2,
                    sh.c * sh.h * sh.w,
                    a_lo as i32,
                    a_hi as i32,
                    &mut rng,
                );
                let filters = 3;
                let wq = MatI32::random_range(
                    sh.geometry().patch_len(),
                    filters,
                    w_lo as i32,
                    w_hi as i32,
                    &mut rng,
                );
                let bias: Vec<i32> =
                    (0..filters).map(|_| rng.range_i64(-10, 10) as i32).collect();
                let conv = Conv2dLayer::new(wq.clone(), bias.clone(), sh.geometry(), false)
                    .unwrap();

                // Layer forward (plan cached inside the layer)…
                let mode = ExecMode::Packed(engine.clone());
                conv.prepare(&engine).unwrap();
                let mut layer_stats = DspOpStats::default();
                let via_layer =
                    conv.forward(&x, sh.h, sh.w, &mode, 4, &mut layer_stats).unwrap();

                // …against the one-shot GEMM over the same patches.
                let patches = x.im2col(&sh.spec()).unwrap();
                let (mut one_shot, shot_stats) = engine.matmul(&patches, &wq).unwrap();
                for r in 0..one_shot.rows {
                    for f in 0..one_shot.cols {
                        one_shot.set(r, f, one_shot.get(r, f) + bias[f]);
                    }
                }
                assert_eq!(via_layer, one_shot, "{name}+{corr:?} {sh:?}");
                assert_eq!(layer_stats, shot_stats, "{name}+{corr:?} {sh:?} counters");

                if exact(name, corr, engine.config().delta) {
                    let oracle = direct_conv(&x, &wq, &bias, sh);
                    assert_eq!(via_layer, oracle, "{name}+{corr:?} {sh:?} must be exact");
                }
            }
        }
    }
    assert!(combos >= 30, "only {combos} engine combinations constructed");
}

/// im2col round-trips through col2im whenever patches cover the image.
#[test]
fn im2col_roundtrip_at_integration_level() {
    let mut rng = Rng::new(0x2C01);
    for sh in [
        Shape { c: 1, h: 6, w: 6, k: 3, s: 1, p: 0 },
        Shape { c: 2, h: 5, w: 7, k: 2, s: 2, p: 1 },
        Shape { c: 3, h: 4, w: 4, k: 3, s: 3, p: 1 },
    ] {
        let spec = sh.spec();
        let imgs = MatI32::random_range(4, spec.image_len(), 0, 15, &mut rng);
        let back = imgs.im2col(&spec).unwrap().col2im(&spec).unwrap();
        assert_eq!(back, imgs, "{sh:?}");
    }
}

/// The conv plan cache tracks weight mutation and engine swaps, exactly
/// like the dense layers' cache.
#[test]
fn conv_plan_cache_invalidates_on_mutation_and_engine_swap() {
    let sh = Shape { c: 1, h: 5, w: 5, k: 3, s: 1, p: 0 };
    let mut rng = Rng::new(0xCACE);
    let mut x = MatI32::random_range(2, 25, 0, 15, &mut rng);
    // Pin the pixel the flipped tap reads so the mutation is provably
    // visible in the feature map regardless of the random draw.
    x.set(0, 0, 15);
    let wq = MatI32::random_range(9, 4, -8, 7, &mut rng);
    let mut conv = Conv2dLayer::new(wq, vec![0; 4], sh.geometry(), false).unwrap();

    let rhu = ExecMode::Packed(int4_engine());
    let mut stats = DspOpStats::default();
    let before = conv.forward(&x, 5, 5, &rhu, 4, &mut stats).unwrap();

    // Mutate the (public) weights in place after a plan was cached; flip
    // a tap that a non-zero activation provably touches.
    let flip = conv.dense.weights.get(0, 0);
    conv.dense.weights.set(0, 0, if flip == 7 { -7 } else { 7 });
    let exact = conv.forward(&x, 5, 5, &ExecMode::Exact, 4, &mut stats).unwrap();
    let packed = conv.forward(&x, 5, 5, &rhu, 4, &mut stats).unwrap();
    assert_eq!(packed, exact, "packed conv must track the mutated filter bank");
    assert_ne!(packed, before, "the mutation must actually change the feature map");

    // A differently-configured engine rebuilds rather than reusing…
    let raw = ExecMode::Packed(
        GemmEngine::new(PackingConfig::int4(), Correction::None).unwrap(),
    );
    conv.forward(&x, 5, 5, &raw, 4, &mut stats).unwrap();
    // …and the original engine still serves correct (rebuilt) planes.
    let again = conv.forward(&x, 5, 5, &rhu, 4, &mut stats).unwrap();
    assert_eq!(again, exact);
}

/// Deep-CNN helper shared by the plan-budget tests: three conv stages +
/// head = four plan caches on one budget.
fn deep_cnn(ds: &data::Dataset, seed: u64) -> QuantCnn {
    let specs = [
        StageSpec::conv3x3(4).with_pool(2, 2).unwrap(),
        StageSpec::conv3x3(6),
        StageSpec::conv3x3(8).with_pool(2, 2).unwrap(),
    ];
    QuantCnn::deep(ds, 1, &specs, 4, 4, seed).unwrap()
}

/// Plan-budget accounting exactness: after `prepare`, the budget's
/// resident bytes equal a hand-computed oracle — the sum of
/// `PackedWeights::plane_bytes` over independently planned copies of
/// every layer's weights — and serving (cache hits) never changes it.
#[test]
fn plan_budget_accounting_matches_plane_bytes_oracle() {
    let ds = data::synthetic(24, 3, 64, 0.12, 61);
    let mut cnn = deep_cnn(&ds, 7);
    let budget = PlanBudget::unbounded();
    cnn.attach_plan_budget(&budget);
    assert_eq!(budget.resident_bytes(), 0, "nothing planned yet");

    let engine = int4_engine();
    let mode = ExecMode::Packed(engine.clone());
    cnn.prepare(&mode).unwrap();
    let mut oracle = 0usize;
    for stage in &cnn.stages {
        oracle += engine.plan(&stage.conv.dense.weights).unwrap().plane_bytes();
    }
    oracle += engine.plan(&cnn.head.weights).unwrap().plane_bytes();
    assert!(oracle > 0);
    assert_eq!(budget.resident_bytes(), oracle, "accounting must be byte-exact");
    assert_eq!(budget.resident_plans(), cnn.depth() + 1);
    assert_eq!(budget.evictions(), 0);

    // Serving hits the caches; the accounting is unchanged.
    let x = cnn.quantize_batch(&ds.images).unwrap();
    cnn.forward(&x, &mode).unwrap();
    assert_eq!(budget.resident_bytes(), oracle);
    assert_eq!(budget.evictions(), 0);

    // Recalibration refits the head (a brand-new DenseLayer); the budget
    // attachment must survive the swap, so after re-preparing, the same
    // byte-exact accounting holds (head shape — and thus bytes — is
    // unchanged; the old head's entry is released on drop).
    cnn.calibrate(&ds, 8).unwrap();
    cnn.prepare(&mode).unwrap();
    assert_eq!(budget.resident_plans(), cnn.depth() + 1);
    assert_eq!(budget.resident_bytes(), oracle, "head swap must stay accounted");
}

/// LRU eviction order, observed through the eviction counter: hits never
/// evict, the least-recently-used resident plan is always the victim,
/// and an evicted layer re-plans **bit-identically** on its next use.
#[test]
fn plan_budget_evicts_lru_and_replans_bit_identically() {
    let engine = int4_engine();
    let mode = ExecMode::Packed(engine.clone());
    let mut rng = Rng::new(0xB4D6);
    let g = ConvGeometry::unit(3).unwrap();
    let convs: Vec<Conv2dLayer> = (0..3)
        .map(|_| {
            let w = MatI32::random_range(9, 4, -8, 7, &mut rng);
            Conv2dLayer::new(w, vec![0; 4], g, false).unwrap()
        })
        .collect();
    // All three banks share a shape, so their plans cost the same bytes;
    // the budget fits exactly two of them.
    let per = engine.plan(&convs[0].dense.weights).unwrap().plane_bytes();
    let budget = PlanBudget::new(2 * per);
    for c in &convs {
        c.attach_budget(&budget);
    }
    let x = MatI32::random_range(2, 25, 0, 15, &mut rng);
    let mut stats = DspOpStats::default();
    let mut fwd = |i: usize| convs[i].forward(&x, 5, 5, &mode, 4, &mut stats).unwrap();

    let out0 = fwd(0); // plans {0}
    let out1 = fwd(1); // plans {0,1}
    assert_eq!(budget.resident_plans(), 2);
    assert_eq!(budget.evictions(), 0);
    fwd(2); // over budget: LRU victim is 0 → {1,2}
    assert_eq!(budget.evictions(), 1);
    assert_eq!(budget.resident_plans(), 2);
    assert_eq!(budget.resident_bytes(), 2 * per);
    let again1 = fwd(1); // hit: no eviction, bumps 1's recency → LRU is 2
    assert_eq!(budget.evictions(), 1, "cache hits never evict");
    assert_eq!(again1, out1);
    let again0 = fwd(0); // miss (evicted): re-plan, victim is 2 → {1,0}
    assert_eq!(budget.evictions(), 2);
    assert_eq!(again0, out0, "re-planned-after-eviction output is bit-identical");
    fwd(2); // miss: victim is the now-LRU 1 → {0,2}
    assert_eq!(budget.evictions(), 3);
    let again0b = fwd(0); // hit again: 0 stayed resident through 2's re-plan
    assert_eq!(budget.evictions(), 3, "most-recently-used plan survived");
    assert_eq!(again0b, out0);
    assert_eq!(budget.resident_plans(), 2);
}

/// A deep CNN under a budget that can hold only one plan thrashes
/// (every layer evicts its predecessor) yet stays bit-identical to the
/// unbudgeted run — outputs *and* `DspOpStats` (planning is off the DSP
/// books) — across repeated forwards.
#[test]
fn deep_cnn_under_tight_budget_is_bit_identical() {
    let ds = data::synthetic(24, 3, 64, 0.12, 67);
    let cnn = deep_cnn(&ds, 11);
    let mode = ExecMode::Packed(int4_engine());
    let x = cnn.quantize_batch(&ds.images).unwrap();
    let (unbudgeted, s0) = cnn.forward(&x, &mode).unwrap();

    // One-plan budget: every store exceeds it, evicting all others.
    let budget = PlanBudget::new(1);
    cnn.attach_plan_budget(&budget);
    let (tight, s1) = cnn.forward(&x, &mode).unwrap();
    assert_eq!(unbudgeted, tight, "eviction-forced re-planning is bit-identical");
    assert_eq!(s0, s1, "planning cost never touches the DSP counters");
    assert!(budget.evictions() > 0, "the tight budget must actually evict");
    assert_eq!(budget.resident_plans(), 1, "only the most recent plan stays");
    let (tight2, s2) = cnn.forward(&x, &mode).unwrap();
    assert_eq!(tight, tight2);
    assert_eq!(s1, s2);
}

/// Batch-resident im2col patch buffers: reuse is bit-identical to
/// rebuild-per-forward (outputs and `DspOpStats`), resident bytes are
/// accounted exactly in a separately attached budget, and a tight patch
/// budget thrashes without changing a single bit.
#[test]
fn patch_buffers_reuse_account_and_evict_bit_identically() {
    let ds = data::synthetic(24, 3, 64, 0.12, 83);
    let cnn = deep_cnn(&ds, 19);
    let mode = ExecMode::Packed(int4_engine());
    let x = cnn.quantize_batch(&ds.images).unwrap();

    // Warm (buffers resident from the first forward) vs forced rebuild.
    let (warm, s1) = cnn.forward(&x, &mode).unwrap();
    assert!(cnn.patch_bytes() > 0, "forward must leave patches resident");
    let (hit, s2) = cnn.forward(&x, &mode).unwrap();
    assert_eq!(warm, hit, "patch reuse must be bit-identical");
    assert_eq!(s1, s2);
    cnn.clear_patches();
    assert_eq!(cnn.patch_bytes(), 0);
    let (rebuilt, s3) = cnn.forward(&x, &mode).unwrap();
    assert_eq!(warm, rebuilt, "rebuild-per-forward must be bit-identical");
    assert_eq!(s1, s3);

    // Patch budget (separate from the plan budget): byte-exact
    // accounting against the layers' own residency counters.
    let budget = PlanBudget::unbounded();
    cnn.attach_patch_budget(&budget);
    cnn.forward(&x, &mode).unwrap();
    assert_eq!(budget.resident_bytes(), cnn.patch_bytes());
    assert_eq!(budget.resident_plans(), cnn.depth(), "one buffer per conv stage");
    assert_eq!(budget.evictions(), 0);

    // A one-byte ceiling evicts every stage's predecessor yet stays
    // bit-identical — and the DSP counters never see the difference.
    let tight = PlanBudget::new(1);
    cnn.attach_patch_budget(&tight);
    let (thrashed, s4) = cnn.forward(&x, &mode).unwrap();
    assert_eq!(warm, thrashed, "patch eviction must not change outputs");
    assert_eq!(s1, s4, "im2col rebuilds never touch DspOpStats");
    assert!(tight.evictions() > 0, "the tight budget must actually evict");
    assert_eq!(tight.resident_plans(), 1, "only the newest unroll survives");
}

/// The coordinator serves the CNN backend end to end: batched predictions
/// equal direct inference, and the packed fabric's utilization shows up
/// in the metrics.
#[test]
fn coordinator_serves_the_cnn_backend() {
    let ds = data::synthetic(64, 3, 64, 0.12, 91);
    let cnn = QuantCnn::new(&ds, 4, 4, 4, 17).unwrap();
    let backend = Arc::new(PackedNnBackend::new(cnn, ExecMode::Packed(int4_engine())));
    assert_eq!(backend.name(), "cnn:packed:xilinx-int4");
    // Oracle per image: the sequential blocking client below keeps the
    // queue depth at 1, so every served batch is a single image and
    // quantizes with that image's own scale — the oracle must do the
    // same (a batch-of-64 oracle would quantize with the batch-global
    // scale and can legitimately disagree).
    let direct: Vec<usize> = ds
        .images
        .iter()
        .map(|img| backend.infer(std::slice::from_ref(img)).unwrap().0[0])
        .collect();

    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();
    for (i, img) in ds.images.iter().enumerate() {
        let pred = handle.infer(Request::new(i as u64, img.clone())).unwrap();
        assert_eq!(pred.id, i as u64);
        assert_eq!(pred.class(), Some(direct[i]), "batched CNN result equals direct");
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 64);
    assert_eq!(m.rejected, 0);
    assert!(m.dsp_utilization > 3.9, "int4 packs 4 mults/cycle");
}
