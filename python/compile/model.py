"""L2: the quantized MLP whose matmuls run through the L1 packed kernel.

A two-layer MLP classifier over the synthetic 8x8 dataset (matching
`rust/src/nn/data.rs`):

    x (B, 64) in [0,1]  --quantize u4-->  h = relu(x_q @ W1_q) >> s1
                        --packed matmul-->  logits = h_q @ W2_q

Both layers' integer matmuls go through `kernels.packed_matmul`, so the
whole forward pass lowers into the same HLO as the packing arithmetic —
one artifact, no python on the serving path. Weight training happens at
build time (plain jax autodiff, `train()`), and the float weights are also
exported for the Rust-side packed engine to consume.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.packed_matmul import packed_matmul

A_BITS = 4
W_BITS = 4


def quantize_unsigned(x, bits=A_BITS):
    """[0,1] floats -> unsigned `bits`-bit codes (fixed scale)."""
    top = (1 << bits) - 1
    return jnp.clip(jnp.round(x * top), 0, top).astype(jnp.int64)


def quantize_signed(w, bits=W_BITS):
    """floats -> symmetric signed `bits`-bit codes; returns (codes, scale)."""
    top = (1 << (bits - 1)) - 1
    scale = top / jnp.maximum(jnp.max(jnp.abs(w)), 1e-6)
    return jnp.clip(jnp.round(w * scale), -(top + 1), top).astype(jnp.int64), scale


def mlp_forward_float(params, x):
    """Float reference forward (training-time)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def train(params, images, labels, steps=300, lr=0.5):
    """Full-batch softmax-CE gradient descent (build-time only)."""

    def loss(p):
        logits = mlp_forward_float(p, images)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))

    @jax.jit
    def step(p):
        g = jax.grad(loss)(p)
        return jax.tree_util.tree_map(lambda v, gv: v - lr * gv, p, g)

    for _ in range(steps):
        params = step(params)
    return params


def init_params(key, dims=(64, 32, 4)):
    """Small dense-dense MLP parameters."""
    k1, k2 = jax.random.split(key)
    d_in, d_h, d_out = dims
    return {
        "w1": jax.random.normal(k1, (d_in, d_h)) * 0.2,
        "b1": jnp.zeros((d_h,)),
        "w2": jax.random.normal(k2, (d_h, d_out)) * 0.2,
        "b2": jnp.zeros((d_out,)),
    }


def quantize_params(params, calibration_x=None):
    """Freeze float weights into integer codes + requantization shift.

    `calibration_x`: float batch used to pick the smallest right-shift
    that brings the layer-1 accumulators into the activation range
    (mirrors `rust/src/nn/quantize.rs::calibrate_shift`). Without it, a
    conservative default is derived from the worst-case accumulator.
    """
    w1_q, s1 = quantize_signed(params["w1"])
    w2_q, s2 = quantize_signed(params["w2"])
    top = (1 << A_BITS) - 1
    if calibration_x is not None:
        x_q = quantize_unsigned(calibration_x)
        acc1 = ref.exact_matmul(x_q, w1_q)
        hi = int(jnp.maximum(jnp.max(acc1), 1))
    else:
        hi = int(jnp.sum(jnp.maximum(w1_q, 0), axis=0).max()) * top
    shift1 = 0
    while (hi >> shift1) > top:
        shift1 += 1
    return {
        "w1_q": w1_q,
        "w2_q": w2_q,
        "shift1": shift1,
        "w1_scale": s1,
        "w2_scale": s2,
    }


def mlp_forward_packed(qparams, x, use_kernel=True):
    """Quantized forward pass, matmuls on the packed kernel.

    x: (B, 64) floats in [0,1]. Returns (B, classes) int64 logits.
    `use_kernel=False` swaps in the pure-jnp packed reference (oracle).
    """
    mm = packed_matmul if use_kernel else ref.packed_matmul_reference
    x_q = quantize_unsigned(x)
    acc1 = mm(x_q, qparams["w1_q"])  # (B, hidden) int64
    h_q = jnp.clip(acc1 >> qparams["shift1"], 0, (1 << A_BITS) - 1)
    return mm(h_q, qparams["w2_q"])  # (B, classes)


def mlp_forward_exact_quant(qparams, x):
    """Same quantized network with exact integer matmuls (the baseline the
    packed path is validated against — identical when RHU is on)."""
    x_q = quantize_unsigned(x)
    acc1 = ref.exact_matmul(x_q, qparams["w1_q"])
    h_q = jnp.clip(acc1 >> qparams["shift1"], 0, (1 << A_BITS) - 1)
    return ref.exact_matmul(h_q, qparams["w2_q"])
