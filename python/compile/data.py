"""Python port of the Rust synthetic dataset (`rust/src/nn/data.rs`).

Bit-exact SplitMix64 reproduction so the build-time-trained model and the
Rust serving side agree on the data distribution (same seeds => same
prototypes => same classes).
"""

MASK64 = (1 << 64) - 1


class Rng:
    """SplitMix64 — mirrors rust/src/util/rng.rs exactly."""

    def __init__(self, seed):
        self.state = seed & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def chance(self, p):
        return self.f64() < p


def prototypes(classes, dim, seed):
    """Per-class blocky patterns — mirrors data::prototypes."""
    rng = Rng(seed)
    protos = []
    for _ in range(classes):
        row = []
        for _ in range(dim):
            if rng.chance(0.3):
                row.append(0.6 + 0.4 * rng.f64())
            else:
                row.append(0.0)
        protos.append(row)
    return protos


def synthetic(n, classes, dim, noise, seed):
    """Mirrors data::synthetic: returns (images, labels)."""
    protos = prototypes(classes, dim, seed)
    rng = Rng(seed ^ 0x5A5A5A5A)
    images, labels = [], []
    for _ in range(n):
        label = rng.below(classes)
        img = []
        for p in protos[label]:
            jitter = (rng.f64() - 0.5) * 2.0 * noise
            if rng.chance(0.05):
                img.append(0.0)
            else:
                img.append(min(max(p + jitter, 0.0), 1.0))
        images.append(img)
        labels.append(label)
    return images, labels
