"""L1 Pallas kernel: INT4-packed quantized matmul.

The paper's compute hot-spot — many low-precision multiplications packed
into one wide multiplier — re-thought for a vector unit (see DESIGN.md
SS Hardware-Adaptation): the DSP48E2's 48-bit P word becomes a lane-local
int64; the B-port packing `a1*2^11 + a0` becomes a vectorized pack over
row pairs; the DSP array becomes the lane grid; the HBM->VMEM BlockSpec
tiling plays the role of the FPGA's BRAM->DSP operand feed. Extraction
(shift/mask sign-extend) and the SS V-A round-half-up correction are
elementwise lane ops fused into the same kernel.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Tile sizes: row-pairs per block x K. Chosen so one block's working set
# (packed A tile + packed W tile + P tile, int64) stays well inside a
# ~16 MiB VMEM budget; see DESIGN.md SS Perf for the footprint math.
DEFAULT_BLOCK_M2 = 64  # row pairs (=> 128 output rows per block)


def _packed_matmul_kernel(pa_ref, pw_ref, out_ref, *, k_dim, rhu):
    """One grid step: (BM2, K) packed-A x (K, N2) packed-W -> 4 results.

    Operands arrive pre-packed (the pack is a cheap reshape+shift done in
    the surrounding jit; keeping it outside the kernel halves the VMEM
    traffic — packed words are half as many as raw operands).
    """
    pa = pa_ref[...]
    pw = pw_ref[...]
    bm2, n2 = pa.shape[0], pw.shape[1]
    acc00 = jnp.zeros((bm2, n2), jnp.int64)
    acc10 = jnp.zeros((bm2, n2), jnp.int64)
    acc01 = jnp.zeros((bm2, n2), jnp.int64)
    acc11 = jnp.zeros((bm2, n2), jnp.int64)
    # Cascade rhythm: accumulate 2**delta wide products per P word, then
    # drain (extract + correct) into the four per-result accumulators.
    for k0 in range(0, k_dim, ref.INT4_DRAIN):
        k1 = min(k0 + ref.INT4_DRAIN, k_dim)
        p = jax.lax.dot_general(
            pa[:, k0:k1],
            pw[k0:k1, :],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int64,
        )
        r00, r10, r01, r11 = ref.extract_int4(p, rhu=rhu, extra_bits=ref.INT4_DELTA)
        acc00 += r00
        acc10 += r10
        acc01 += r01
        acc11 += r11
    # Interleave the four result planes back into (2*BM2, 2*N2).
    out = jnp.zeros(out_ref.shape, jnp.int64)
    out = out.at[0::2, 0::2].set(acc00)
    out = out.at[1::2, 0::2].set(acc10)
    out = out.at[0::2, 1::2].set(acc01)
    out = out.at[1::2, 1::2].set(acc11)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("rhu", "block_m2"))
def packed_matmul(a, w, rhu=True, block_m2=DEFAULT_BLOCK_M2):
    """INT4-packed matmul via the Pallas kernel.

    a: (M, K) unsigned 4-bit values (any int dtype); M even.
    w: (K, N) signed 4-bit values; N even.
    Returns (M, N) int64 — bit-identical to the DSP cascade with the
    SS V-A full correction (rhu=True) or the raw Xilinx scheme (rhu=False).
    """
    m, k_dim = a.shape
    _, n = w.shape
    assert m % 2 == 0 and n % 2 == 0, "row/col pairs required"
    # Pack outside the kernel (cheap, halves VMEM traffic).
    packed_a = ref.pack_a_pair(a[0::2, :], a[1::2, :])
    packed_w = ref.pack_w_pair(w[:, 0::2], w[:, 1::2])
    m2, n2 = m // 2, n // 2
    bm2 = min(block_m2, m2)
    # Grid over row-pair blocks; W is broadcast to every block.
    grid = (pl.cdiv(m2, bm2),)
    kernel = functools.partial(_packed_matmul_kernel, k_dim=k_dim, rhu=rhu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm2, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((k_dim, n2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2 * bm2, 2 * n2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=True,
    )(packed_a, packed_w)
