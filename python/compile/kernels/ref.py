"""Pure-jnp oracle for the packed-arithmetic kernels.

Mirrors the bit-level semantics of the Rust substrate
(`rust/src/packing/`): INT4 packing per Xilinx wp521 / the paper's Eqn. (3),
plain (floor) extraction, round-half-up full correction (SS V-A), and the
architecture-independent INT-N product (Eqn. (4)).

Everything operates on int64 (the 48-bit P word and the packed operands
need up to 45 bits), so callers must enable jax x64 mode — `import
compile.kernels.ref` does it on import.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

# The INT4 configuration of the paper (SS III/SS IV): delta = 3,
# a offsets {0, 11}, w offsets {0, 22}, result offsets {0, 11, 22, 33}.
INT4_A_OFFSETS = (0, 11)
INT4_W_OFFSETS = (0, 22)
INT4_R_OFFSETS = (0, 11, 22, 33)
INT4_R_WIDTH = 8
INT4_DELTA = 3
# With delta padding bits, up to 2**delta products accumulate per P word.
INT4_DRAIN = 1 << INT4_DELTA


def exact_matmul(a, w):
    """Exact integer matmul oracle (int64 accumulation)."""
    return jnp.matmul(a.astype(jnp.int64), w.astype(jnp.int64))


def pack_a_pair(a0, a1):
    """Pack two unsigned 4-bit activations into one B-port word (Eqn. 3)."""
    return a0.astype(jnp.int64) + (a1.astype(jnp.int64) << INT4_A_OFFSETS[1])


def pack_w_pair(w0, w1):
    """Pack two signed 4-bit weights into one pre-adder word (Eqn. 3)."""
    return w0.astype(jnp.int64) + (w1.astype(jnp.int64) << INT4_W_OFFSETS[1])


def extract_field(p, offset, width):
    """Plain shift-and-truncate signed field extraction (floors: SS V)."""
    u = (p >> offset) & ((1 << width) - 1)
    sign = 1 << (width - 1)
    return (u ^ sign) - sign


def extract_field_rhu(p, offset, width):
    """Round-half-up extraction (SS V-A full correction)."""
    if offset == 0:
        return extract_field(p, 0, width)
    rounded = (p >> (offset - 1)) + 1
    return extract_field(rounded, 1, width)


def extract_int4(p, rhu=True, extra_bits=0):
    """Extract the four INT4 outer-product results from P words.

    `extra_bits` widens each field into the padding (used when draining
    accumulated P words: after 2**delta cascade steps the per-result sums
    occupy width + delta bits).
    Returns (r00, r10, r01, r11) = (a0w0, a1w0, a0w1, a1w1).
    """
    width = INT4_R_WIDTH + extra_bits
    f = extract_field_rhu if rhu else extract_field
    return tuple(f(p, off, width) for off in INT4_R_OFFSETS)


def packed_matmul_reference(a, w, rhu=True):
    """INT4-packed quantized matmul, pure jnp (the kernel's oracle).

    a: (M, K) int, unsigned 4-bit values; M must be even.
    w: (K, N) int, signed 4-bit values; N must be even.

    Each (row-pair, col-pair, k) triple is one virtual DSP multiply whose
    P word carries four products; chunks of 2**delta k-steps accumulate in
    the P word before draining (the cascade rhythm of SS III).
    """
    m, k_dim = a.shape
    k2, n = w.shape
    assert k_dim == k2 and m % 2 == 0 and n % 2 == 0
    a = a.astype(jnp.int64)
    w = w.astype(jnp.int64)

    packed_a = pack_a_pair(a[0::2, :], a[1::2, :])  # (M/2, K)
    packed_w = pack_w_pair(w[:, 0::2], w[:, 1::2])  # (K, N/2)

    out = jnp.zeros((m, n), dtype=jnp.int64)
    for k0 in range(0, k_dim, INT4_DRAIN):
        chunk = slice(k0, min(k0 + INT4_DRAIN, k_dim))
        # One packed wide multiply per (m2, k, n2); cascade-accumulate the
        # chunk inside the P word (a plain matmul in the packed domain).
        p = jnp.matmul(packed_a[:, chunk], packed_w[chunk, :])  # (M/2, N/2)
        r00, r10, r01, r11 = extract_int4(p, rhu=rhu, extra_bits=INT4_DELTA)
        out = out.at[0::2, 0::2].add(r00)
        out = out.at[1::2, 0::2].add(r10)
        out = out.at[0::2, 1::2].add(r01)
        out = out.at[1::2, 1::2].add(r11)
    return out


def intn_product(a_vals, w_vals, a_offsets, w_offsets):
    """Architecture-independent INT-N packed product (Eqn. (4)) for one
    operand-vector pair; returns the raw wide product (python int)."""
    pa = sum(int(v) << o for v, o in zip(a_vals, a_offsets))
    pw = sum(int(v) << o for v, o in zip(w_vals, w_offsets))
    return pa * pw
