"""AOT build: train the L2 model, lower exact + packed variants to HLO
text, export weights for the Rust packed engine.

Run once by `make artifacts`; Python never appears on the serving path.

Artifacts (all under --out-dir):
  mlp_exact.hlo.txt   exact-quantized forward pass       (PJRT backend)
  mlp_packed.hlo.txt  packed-kernel forward pass         (PJRT backend)
  mlp_weights.txt     float + quantized weights          (Rust engine)
  manifest.txt        shapes and metadata

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids. See /opt/xla-example/README.md.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data, model

BATCH = 16
DIM = 64
HIDDEN = 32
CLASSES = 4
SEED = 7  # must match the Rust examples (data::synthetic seed)


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    `print_large_constants=True` is essential: the default printer elides
    big constants as `constant({...})`, which parses on the Rust side but
    zeroes the baked weights — the model would silently predict garbage.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax's printer emits source_end_line metadata that the 0.5.1 text
    # parser rejects; metadata is irrelevant to execution anyway.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def export_weights(path, params, qparams):
    """Plain-text weight dump: `name rows cols` header then one row per
    line — trivially parsed by the Rust side (no JSON dependency)."""
    with open(path, "w") as f:
        for name in ("w1", "b1", "w2", "b2"):
            arr = jnp.atleast_2d(params[name])
            f.write(f"{name} {arr.shape[0]} {arr.shape[1]}\n")
            for row in arr.tolist():
                f.write(" ".join(f"{v:.8g}" for v in row) + "\n")
        f.write(f"shift1 1 1\n{qparams['shift1']}\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--train-samples", type=int, default=512)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # 1. Train on the shared synthetic dataset (bit-identical to Rust's).
    images, labels = data.synthetic(args.train_samples, CLASSES, DIM, 0.15, SEED)
    x = jnp.asarray(images, dtype=jnp.float32)
    y = jnp.asarray(labels, dtype=jnp.int32)
    params = model.init_params(jax.random.PRNGKey(0), (DIM, HIDDEN, CLASSES))
    params = model.train(params, x, y, steps=args.train_steps)
    logits = model.mlp_forward_float(params, x)
    acc = float(jnp.mean(jnp.argmax(logits, axis=1) == y))
    print(f"float train accuracy: {acc:.3f}")

    qparams = model.quantize_params(params, calibration_x=x)
    q_logits = model.mlp_forward_exact_quant(qparams, x)
    q_acc = float(jnp.mean(jnp.argmax(q_logits, axis=1) == y))
    print(f"quantized (shift1={qparams['shift1']}) accuracy: {q_acc:.3f}")

    # 2. Lower both variants for a fixed batch.
    spec = jax.ShapeDtypeStruct((BATCH, DIM), jnp.float32)

    def packed_fn(xb):
        return (model.mlp_forward_packed(qparams, xb).astype(jnp.float32),)

    def exact_fn(xb):
        return (model.mlp_forward_exact_quant(qparams, xb).astype(jnp.float32),)

    for name, fn in (("mlp_packed", packed_fn), ("mlp_exact", exact_fn)):
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # 3. Export weights for the Rust packed engine.
    wpath = os.path.join(args.out_dir, "mlp_weights.txt")
    export_weights(wpath, params, qparams)
    print(f"wrote {wpath}")

    # 4. Manifest.
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(
            f"batch {BATCH}\ndim {DIM}\nhidden {HIDDEN}\nclasses {CLASSES}\n"
            f"seed {SEED}\nfloat_accuracy {acc:.4f}\n"
        )
    print("manifest written; artifacts complete")


if __name__ == "__main__":
    main()
