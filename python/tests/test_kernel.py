"""L1 kernel correctness: Pallas packed matmul vs the pure-jnp oracle and
vs exact integer matmul — the core correctness signal of the build.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.packed_matmul import packed_matmul

rng = np.random.default_rng(7)


def random_operands(m, k, n, seed=None):
    r = np.random.default_rng(seed if seed is not None else rng.integers(1 << 30))
    a = r.integers(0, 16, size=(m, k), dtype=np.int64)
    w = r.integers(-8, 8, size=(k, n), dtype=np.int64)
    return a, w


class TestScalarSemantics:
    """Bit-level pack/extract semantics against hand-computed values."""

    def test_eqn3_packing(self):
        # (a1*2^11 + a0) * (w1*2^22 + w0)
        assert int(ref.pack_a_pair(np.int64(3), np.int64(10))) == (10 << 11) + 3
        assert int(ref.pack_w_pair(np.int64(-7), np.int64(-4))) == -7 + (-4 << 22)

    def test_floor_error_minus_one(self):
        # a=[3,0], w=[-7,0]: r0 = -21 exact, r1 floors to -1 (SS V).
        p = ref.intn_product([3, 0], [-7, 0], ref.INT4_A_OFFSETS, ref.INT4_W_OFFSETS)
        p = np.int64(p)
        assert int(ref.extract_field(p, 0, 8)) == -21
        assert int(ref.extract_field(p, 11, 8)) == -1
        # Round-half-up restores the exact 0.
        assert int(ref.extract_field_rhu(p, 11, 8)) == 0

    def test_exhaustive_int4_single_product(self):
        # All 16^2*16^2 combos: RHU extraction is exact, floor is -1-bounded.
        a0, a1 = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
        for w0 in range(-8, 8):
            for w1 in range(-8, 8):
                pa = ref.pack_a_pair(np.int64(a0), np.int64(a1))
                pw = int(ref.pack_w_pair(np.int64(w0), np.int64(w1)))
                p = pa * pw
                r00, r10, r01, r11 = ref.extract_int4(p, rhu=True)
                np.testing.assert_array_equal(np.asarray(r00), a0 * w0)
                np.testing.assert_array_equal(np.asarray(r10), a1 * w0)
                np.testing.assert_array_equal(np.asarray(r01), a0 * w1)
                np.testing.assert_array_equal(np.asarray(r11), a1 * w1)
                raw = ref.extract_int4(p, rhu=False)
                for got, exp in zip(raw, (a0 * w0, a1 * w0, a0 * w1, a1 * w1)):
                    err = np.asarray(got) - exp
                    assert err.min() >= -1 and err.max() <= 0


class TestReferenceMatmul:
    """Pure-jnp packed reference vs exact matmul."""

    @pytest.mark.parametrize("m,k,n", [(2, 1, 2), (4, 8, 4), (6, 16, 2), (8, 33, 6)])
    def test_rhu_matches_exact(self, m, k, n):
        a, w = random_operands(m, k, n, seed=m * 100 + k * 10 + n)
        got = np.asarray(ref.packed_matmul_reference(a, w, rhu=True))
        np.testing.assert_array_equal(got, a @ w)

    def test_raw_floor_bias(self):
        a, w = random_operands(16, 64, 8, seed=3)
        got = np.asarray(ref.packed_matmul_reference(a, w, rhu=False))
        err = got - a @ w
        assert err.max() <= 0, "floor bias is toward -inf"
        assert err.min() >= -(64 // 8) * 2, "bounded by drains"
        assert (err != 0).any(), "raw packing does err"


class TestPallasKernel:
    """The Pallas kernel is bit-identical to the oracle."""

    @pytest.mark.parametrize("m,k,n", [(2, 4, 2), (8, 16, 4), (16, 24, 8), (128, 33, 10)])
    def test_kernel_matches_exact(self, m, k, n):
        a, w = random_operands(m, k, n, seed=m + k + n)
        got = np.asarray(packed_matmul(a, w, rhu=True))
        np.testing.assert_array_equal(got, a @ w)

    def test_kernel_matches_reference_raw(self):
        a, w = random_operands(8, 40, 6, seed=11)
        got = np.asarray(packed_matmul(a, w, rhu=False))
        exp = np.asarray(ref.packed_matmul_reference(a, w, rhu=False))
        np.testing.assert_array_equal(got, exp)

    def test_kernel_blocks_tile_correctly(self):
        # Force multiple grid steps with a small block size.
        a, w = random_operands(32, 16, 4, seed=13)
        got = np.asarray(packed_matmul(a, w, rhu=True, block_m2=4))
        np.testing.assert_array_equal(got, a @ w)

    @settings(max_examples=25, deadline=None)
    @given(
        m2=st.integers(1, 8),
        k=st.integers(1, 40),
        n2=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_kernel_hypothesis_sweep(self, m2, k, n2, seed):
        a, w = random_operands(2 * m2, k, 2 * n2, seed=seed)
        got = np.asarray(packed_matmul(a, w, rhu=True))
        np.testing.assert_array_equal(got, a @ w)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_kernel_dtype_robustness(self, seed):
        # int32 / int8 inputs upcast identically.
        r = np.random.default_rng(seed)
        a = r.integers(0, 16, size=(4, 12), dtype=np.int32)
        w = r.integers(-8, 8, size=(12, 4), dtype=np.int8)
        got = np.asarray(packed_matmul(a, w))
        np.testing.assert_array_equal(got, a.astype(np.int64) @ w.astype(np.int64))
