"""Cross-language parity: the Python SplitMix64 port must match the Rust
implementation bit for bit (golden values are asserted on both sides)."""

from compile import data


def test_splitmix64_golden_values():
    r = data.Rng(42)
    assert [r.next_u64() for _ in range(4)] == [
        0xBDD732262FEB6E95,
        0x28EFE333B266F103,
        0x47526757130F9F52,
        0x581CE1FF0E4AE394,
    ]


def test_f64_golden_values():
    r = data.Rng(7)
    got = [r.f64() for _ in range(3)]
    exp = [0.3898297483912715, 0.01678829452815611, 0.9007606806068834]
    assert all(abs(g - e) < 1e-15 for g, e in zip(got, exp))


def test_synthetic_shapes_and_determinism():
    img1, lab1 = data.synthetic(50, 4, 64, 0.15, 7)
    img2, lab2 = data.synthetic(50, 4, 64, 0.15, 7)
    assert img1 == img2 and lab1 == lab2
    assert len(img1) == 50 and all(len(i) == 64 for i in img1)
    assert all(0 <= l < 4 for l in lab1)
    assert all(0.0 <= v <= 1.0 for img in img1 for v in img)
