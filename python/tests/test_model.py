"""L2 model correctness: packed forward equals exact-quant forward, and
the model learns the synthetic task at build time."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model


@pytest.fixture(scope="module")
def trained():
    images, labels = data.synthetic(256, 4, 64, 0.15, 7)
    x = jnp.asarray(images, dtype=jnp.float32)
    y = jnp.asarray(labels, dtype=jnp.int32)
    params = model.init_params(jax.random.PRNGKey(0))
    params = model.train(params, x, y, steps=150)
    return params, x, y


def test_training_learns(trained):
    params, x, y = trained
    logits = model.mlp_forward_float(params, x)
    acc = float(jnp.mean(jnp.argmax(logits, axis=1) == y))
    assert acc > 0.9, f"float accuracy {acc}"


def test_packed_equals_exact_quant(trained):
    params, x, _ = trained
    q = model.quantize_params(params)
    packed = model.mlp_forward_packed(q, x[:16], use_kernel=True)
    exact = model.mlp_forward_exact_quant(q, x[:16])
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(exact))


def test_packed_reference_path_agrees(trained):
    params, x, _ = trained
    q = model.quantize_params(params)
    via_kernel = model.mlp_forward_packed(q, x[:8], use_kernel=True)
    via_ref = model.mlp_forward_packed(q, x[:8], use_kernel=False)
    np.testing.assert_array_equal(np.asarray(via_kernel), np.asarray(via_ref))


def test_quantized_accuracy_close_to_float(trained):
    params, x, y = trained
    q = model.quantize_params(params)
    logits = model.mlp_forward_packed(q, x, use_kernel=False)
    acc = float(jnp.mean(jnp.argmax(logits, axis=1) == y))
    assert acc > 0.8, f"quantized accuracy {acc}"


def test_weight_codes_in_packing_range(trained):
    params, _, _ = trained
    q = model.quantize_params(params)
    for k in ("w1_q", "w2_q"):
        arr = np.asarray(q[k])
        assert arr.min() >= -8 and arr.max() <= 7, k
