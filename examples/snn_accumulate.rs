//! SNN addition-packing example (§VII): run a spiking layer whose membrane
//! accumulators are packed five-to-a-DSP, with and without guard bits, and
//! compare spike fidelity and DSP cost against dedicated fabric adders.
//!
//! ```text
//! cargo run --release --example snn_accumulate
//! ```

use dsp_packing::nn::{data, SnnStats, SpikingDense};
use dsp_packing::util::Rng;

fn main() -> dsp_packing::Result<()> {
    let neurons = 40;
    let inputs = 64;
    let steps = 64;
    let n_samples = 100;

    // Input spike trains from the synthetic image dataset (rate coding).
    let ds = data::synthetic(n_samples, 4, inputs, 0.15, 7);
    let trains = data::to_spike_trains(&ds, steps, 11);

    // Deterministic small integer weights.
    let mut rng = Rng::new(99);
    let weights: Vec<Vec<i32>> = (0..neurons)
        .map(|_| (0..inputs).map(|_| rng.range_i64(-3, 4) as i32).collect())
        .collect();

    println!("SNN layer: {neurons} neurons x {inputs} inputs, {steps} timesteps, {n_samples} samples");
    println!("membranes packed 5-per-DSP at 9 bits (the Table III configuration)\n");

    for (label, guard_bits) in [("no guard bits (approximate)", 0u32), ("1 guard bit (exact)", 1)] {
        // Threshold near the lane ceiling so membranes actually traverse
        // the full 9-bit range — lane wraps (and thus carry leaks in the
        // unguarded case) occur, which is the §VII trade-off on display.
        let mut layer = SpikingDense::new(weights.clone(), 480, 9, 5, guard_bits)?;
        let mut stats = SnnStats::default();
        let mut packed_counts = 0u64;
        for train in &trains {
            layer.reset();
            let counts = layer.run(train, &mut stats)?;
            packed_counts += counts.iter().sum::<u64>();
        }
        println!("{label}:");
        println!("  DSP accumulators: {} (vs {} dedicated fabric adders)", layer.dsps_used(), neurons);
        println!("  spikes packed/exact: {} / {}", stats.packed_spikes, stats.exact_spikes);
        println!("  step agreement: {:.2}%", stats.agreement() * 100.0);
        println!("  total packed spikes: {packed_counts}\n");
    }

    println!("guard bits buy exactness for 1 ALU bit per lane boundary (Fig. 8);");
    println!("without them the carry leak perturbs LSBs only (WCE = 1, Fig. 7).");
    Ok(())
}
