//! SNN addition-packing example (§VII): the carry-leak trade-off at the
//! accumulator level, a spiking layer whose membranes are packed
//! five-to-a-DSP on the plan/execute accumulate datapath, and the layer
//! served as a spike-train backend through the coordinator.
//!
//! ```text
//! cargo run --release --example snn_accumulate
//! ```

use dsp_packing::addpack::AdditionPacking;
use dsp_packing::coordinator::{
    Coordinator, InferenceBackend, Request, ServerConfig, SpikingBackend,
};
use dsp_packing::nn::{data, SnnStats, SpikingDense};
use dsp_packing::util::Rng;
use std::sync::Arc;

fn main() -> dsp_packing::Result<()> {
    // ── Part 1: the §VII approximation, at the accumulator level ──────
    // Operands near the lane ceiling force carries across lane
    // boundaries: unguarded boundaries leak +1 into the next lane's LSB
    // (WCE = 1, Fig. 7); a guard bit absorbs the carry (Fig. 8).
    let x = [400i128, 300, 200, 500, 100];
    let y = [200i128, 300, 400, 100, 50];
    println!("packed 5x9-bit addition, operands near the lane ceiling:");
    for (label, packing) in [
        ("table3 (no guards)   ", AdditionPacking::table3()),
        ("table3_guarded (3 g) ", AdditionPacking::table3_guarded()?),
    ] {
        let got = packing.add(&x, &y)?;
        let exp = packing.expected(&x, &y);
        let errs: Vec<i128> = got.iter().zip(&exp).map(|(g, e)| g - e).collect();
        println!(
            "  {label} per-lane errors {errs:?}  (fallible lanes: {:?})",
            packing.fallible_lanes()
        );
    }
    println!();

    // ── Part 2: the spiking layer, sized so lanes never wrap ──────────
    let neurons = 40;
    let inputs = 64;
    let steps = 64;
    let n_samples = 100;

    // Input spike trains from the synthetic image dataset (rate coding).
    let ds = data::synthetic(n_samples, 4, inputs, 0.15, 7);
    let trains = data::to_spike_trains(&ds, steps, 11);

    // Deterministic small integer weights. The layer validates that
    // threshold + worst-case step sums fit each 9-bit lane (the old
    // example requested 5x9+4 guard bits = 49 ALU bits and aborted, and
    // its threshold overflowed the lanes besides), so keep magnitudes
    // modest: weights in -1..=2, threshold 200.
    let mut rng = Rng::new(99);
    let weights: Vec<Vec<i32>> = (0..neurons)
        .map(|_| (0..inputs).map(|_| rng.range_i64(-1, 3) as i32).collect())
        .collect();
    let threshold = 200;

    println!(
        "SNN layer: {neurons} neurons x {inputs} inputs, {steps} timesteps, {n_samples} samples"
    );
    println!("membranes packed into 48-bit DSP ALU words, 9-bit lanes\n");

    let configs: [(&str, SpikingDense); 3] = [
        (
            "table3, 5 lanes, no guards",
            SpikingDense::new(weights.clone(), threshold, 9, 5, 0)?,
        ),
        (
            "table3_guarded, 5 lanes, 3 guards",
            SpikingDense::with_packing(
                weights.clone(),
                threshold,
                AdditionPacking::table3_guarded()?,
            )?,
        ),
        (
            "uniform guarded, 4 lanes, 3 guards",
            SpikingDense::new(weights.clone(), threshold, 9, 4, 1)?,
        ),
    ];
    for (label, mut layer) in configs {
        let mut stats = SnnStats::default();
        let mut packed_counts = 0u64;
        for train in &trains {
            layer.reset();
            let counts = layer.run(train, &mut stats)?;
            packed_counts += counts.iter().sum::<u64>();
        }
        println!("{label}:");
        println!(
            "  DSP accumulators: {} (vs {neurons} dedicated fabric adders)",
            layer.dsps_used()
        );
        println!("  spikes packed/exact: {} / {}", stats.packed_spikes, stats.exact_spikes);
        println!("  step agreement: {:.2}%", stats.agreement() * 100.0);
        println!(
            "  ALU passes (dsp_cycles): {}, total packed spikes: {packed_counts}\n",
            stats.dsp.dsp_cycles
        );
    }
    println!("correctly sized membranes never wrap their lanes, so even the");
    println!("unguarded Table III layout runs exactly — the §VII choice buys");
    println!("density (lanes per DSP); the leak risk lives in deliberately");
    println!("wrapping streams like part 1.\n");

    // ── Part 3: served as a spike-train backend ───────────────────────
    let classifier = SpikingDense::prototype_classifier(&ds, 120, 9, 5, 0)?;
    let backend = Arc::new(SpikingBackend::new(classifier, 48));
    let name = backend.name().to_string();
    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();
    let mut correct = 0usize;
    for (i, image) in ds.images.iter().enumerate() {
        let pred = handle.infer(Request::new(i as u64, image.clone()))?;
        if pred.class() == Some(ds.labels[i]) {
            correct += 1;
        }
    }
    let metrics = coord.shutdown();
    println!("served {} spike-train requests through backend '{name}':", ds.images.len());
    println!(
        "  prototype-vote accuracy: {:.1}% ({} classes)",
        100.0 * correct as f64 / ds.images.len() as f64,
        ds.classes
    );
    println!(
        "  completed: {}, mean batch: {:.2}, dsp utilization: {:.2}",
        metrics.completed, metrics.mean_batch, metrics.dsp_utilization
    );
    Ok(())
}
