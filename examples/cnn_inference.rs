//! End-to-end driver (experiment E9): serve quantized inference through
//! the full three-layer stack and compare every backend on the same
//! workload.
//!
//! * **pjrt:mlp_exact** — the L2 JAX model with exact integer matmuls,
//!   AOT-lowered to HLO and executed via PJRT (no Python at runtime).
//! * **pjrt:mlp_packed** — the same model with every matmul routed
//!   through the L1 Pallas DSP-packing kernel, in the same artifact.
//! * **packed:xilinx-int4** — the Rust virtual accelerator: bit-accurate
//!   DSP48E2 slices running INT4 packing with full correction.
//! * **exact** — the Rust exact integer reference.
//!
//! All four serve the identical synthetic dataset (shared SplitMix64
//! generator, seed 7 — bit-identical between Python and Rust) through the
//! L3 coordinator with dynamic batching. Reported: accuracy, throughput,
//! latency percentiles, DSP utilization. Results land in EXPERIMENTS.md.
//!
//! ```text
//! make artifacts && cargo run --release --example cnn_inference
//! ```

use dsp_packing::coordinator::{
    Coordinator, InferenceBackend, PackedNnBackend, Request, ServerConfig,
};
use dsp_packing::correct::Correction;
use dsp_packing::gemm::GemmEngine;
use dsp_packing::nn::{data, weights, ExecMode};
use dsp_packing::packing::PackingConfig;
use dsp_packing::runtime::PjrtBackend;
use std::sync::Arc;
use std::time::Instant;

const REQUESTS: usize = 512;

fn serve(backend: Arc<dyn InferenceBackend>, ds: &data::Dataset) -> dsp_packing::Result<()> {
    let name = backend.name().to_string();
    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();

    // Concurrent clients to keep the batcher busy.
    let start = Instant::now();
    let n_clients = 4;
    let per_client = REQUESTS / n_clients;
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let handle = handle.clone();
        let images = ds.images.clone();
        let labels = ds.labels.clone();
        clients.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..per_client {
                let idx = (c * per_client + i) % images.len();
                let pred = handle
                    .infer(Request { id: (c * per_client + i) as u64, image: images[idx].clone() })
                    .expect("infer");
                if pred.class == labels[idx] {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let elapsed = start.elapsed();
    let m = coord.shutdown();

    println!(
        "{name:<22} acc={:>5.1}%  thrpt={:>7.0} req/s  p50={:>6}us p99={:>6}us  batch={:.1}  dsp-util={:.2}",
        100.0 * correct as f64 / REQUESTS as f64,
        REQUESTS as f64 / elapsed.as_secs_f64(),
        m.p50_latency_us,
        m.p99_latency_us,
        m.mean_batch,
        m.dsp_utilization,
    );
    Ok(())
}

fn main() -> dsp_packing::Result<()> {
    // The dataset both sides agree on (seed 7, bit-identical generators).
    let ds = data::synthetic(256, 4, 64, 0.15, 7);

    // The JAX-trained model weights, exported at `make artifacts` time.
    let weights_path = dsp_packing::runtime::PjrtRuntime::artifact_path("mlp_weights.txt")
        .ok_or_else(|| dsp_packing::Error::Runtime("run `make artifacts` first".into()))?;
    let mut mlp = weights::mlp_from_export(&weights_path)?;
    let cal = mlp.quantize_batch(&ds.images[..32].to_vec())?;
    mlp.calibrate(&cal)?;

    println!("end-to-end inference, {REQUESTS} requests, 4 concurrent clients\n");

    // 1. Rust exact reference.
    serve(Arc::new(PackedNnBackend::new(mlp.clone(), ExecMode::Exact)), &ds)?;

    // 2. Rust virtual accelerator: INT4 packing + full correction.
    let engine = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp)?;
    serve(Arc::new(PackedNnBackend::new(mlp.clone(), ExecMode::Packed(engine))), &ds)?;

    // 3. Rust virtual accelerator: MR-Overpacking (6 mults per DSP).
    let engine6 = GemmEngine::logical(PackingConfig::overpack6_int4(), Correction::MrRestore)?;
    serve(Arc::new(PackedNnBackend::new(mlp.clone(), ExecMode::Packed(engine6))), &ds)?;

    // 4. PJRT: the AOT JAX artifacts (exact and packed-kernel variants).
    for name in ["mlp_exact.hlo.txt", "mlp_packed.hlo.txt"] {
        match PjrtBackend::load(name, 16, 64, 4) {
            Ok(b) => serve(Arc::new(b), &ds)?,
            Err(e) => println!("pjrt:{name:<15} skipped: {e}"),
        }
    }

    println!("\nreading: the packed virtual accelerator matches exact accuracy (full");
    println!("correction is bit-exact) at 4x DSP utilization; MR-Overpacking trades");
    println!("~0 accuracy on this model for 6x; the PJRT rows prove the same");
    println!("arithmetic lowered from JAX/Pallas runs on the rust serving path.");
    Ok(())
}
