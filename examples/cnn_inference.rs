//! End-to-end driver (experiment E9): serve quantized **deep CNN**
//! inference through the full stack — a three-conv-stage model
//! (conv→pool → conv → conv→pool → dense head, every matmul lowered to
//! the packed GEMM via im2col) — and compare backends on the same
//! workload:
//!
//! * **cnn:exact** — the deep CNN on the exact i32 reference path.
//! * **cnn:packed:xilinx-int4** — the same CNN on the Rust virtual
//!   accelerator: bit-accurate DSP48E2 slices running INT4 packing with
//!   full correction (bit-identical logits to `cnn:exact`, asserted
//!   before serving).
//! * **cnn:packed:overpack6-int4** — MR-Overpacking, six multiplications
//!   per DSP, small bounded approximation error.
//! * **cnn:adaptive** — the precision router: each request carries an
//!   error budget in an appended metadata channel; exact-budget requests
//!   run the INT4-corrected fabric, tolerant ones the MR-Overpacking
//!   fabric. One model replica per fabric keeps both plan sets resident,
//!   under a shared plan-cache byte budget ([`dsp_packing::nn::PlanBudget`]).
//! * **exact / packed:...** — the original MLP backends on the same
//!   dataset, for cross-model comparison (requires `make artifacts` for
//!   the JAX-trained weights; skipped otherwise).
//! * **pjrt:...** — the AOT JAX/Pallas artifacts via PJRT, when built.
//!
//! Every backend serves the identical synthetic dataset (shared SplitMix64
//! generator, seed 7) through the L3 coordinator with dynamic batching.
//! Reported: accuracy, throughput, latency percentiles, DSP utilization.
//!
//! ```text
//! cargo run --release --example cnn_inference           # CNN rows always run
//! make artifacts && cargo run --release --example cnn_inference  # + MLP/PJRT
//! ```

use dsp_packing::coordinator::{
    AdaptiveBackend, BudgetChannelPolicy, Coordinator, InferenceBackend, PackedNnBackend,
    Request, ServerConfig,
};
use dsp_packing::correct::Correction;
use dsp_packing::gemm::GemmEngine;
use dsp_packing::nn::{data, weights, ExecMode, NnModel, PlanBudget, QuantCnn, StageSpec};
use dsp_packing::packing::PackingConfig;
use dsp_packing::runtime::PjrtBackend;
use std::sync::Arc;
use std::time::Instant;

const REQUESTS: usize = 512;

fn serve(backend: Arc<dyn InferenceBackend>, ds: &data::Dataset) -> dsp_packing::Result<()> {
    let name = backend.name().to_string();
    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();

    // Concurrent clients to keep the batcher busy.
    let start = Instant::now();
    let n_clients = 4;
    let per_client = REQUESTS / n_clients;
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let handle = handle.clone();
        let images = ds.images.clone();
        let labels = ds.labels.clone();
        clients.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..per_client {
                let idx = (c * per_client + i) % images.len();
                let pred = handle
                    .infer(Request::new((c * per_client + i) as u64, images[idx].clone()))
                    .expect("infer");
                if pred.class() == Some(labels[idx]) {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let elapsed = start.elapsed();
    let m = coord.shutdown();

    println!(
        "{name:<26} acc={:>5.1}%  thrpt={:>7.0} req/s  p50={:>6}us p99={:>6}us  batch={:.1}  dsp-util={:.2}",
        100.0 * correct as f64 / REQUESTS as f64,
        REQUESTS as f64 / elapsed.as_secs_f64(),
        m.p50_latency_us,
        m.p99_latency_us,
        m.mean_batch,
        m.dsp_utilization,
    );
    Ok(())
}

fn with_budget(img: &[f32], budget: f32) -> Vec<f32> {
    let mut v = img.to_vec();
    v.push(budget);
    v
}

fn main() -> dsp_packing::Result<()> {
    // The dataset both sides agree on (seed 7, bit-identical generators).
    let ds = data::synthetic(256, 4, 64, 0.15, 7);

    println!("end-to-end deep-CNN inference, {REQUESTS} requests, 4 concurrent clients\n");

    // The deep quantized CNN: three 3×3 conv stages (8 → 12 → 16
    // filters, pooling after the first and last) and a centroid head —
    // every per-stage requant shift calibrated stage by stage, every
    // filter bank planned once into resident weight planes.
    let specs = [
        StageSpec::conv3x3(8).with_pool(2, 2)?,
        StageSpec::conv3x3(12),
        StageSpec::conv3x3(16).with_pool(2, 2)?,
    ];
    let cnn = QuantCnn::deep(&ds, 1, &specs, 4, 4, 17)?;
    println!("model: {} conv stages, head over {} features\n", cnn.depth(), cnn.head.weights.rows);

    // 1. Deep CNN on the exact i32 reference.
    serve(Arc::new(PackedNnBackend::new(cnn.clone(), ExecMode::Exact)), &ds)?;

    // 2. Deep CNN on the virtual accelerator: INT4 packing + full
    //    correction (bit-identical to exact — asserted below via the
    //    adaptive backend's exact route).
    let engine = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp)?;
    serve(Arc::new(PackedNnBackend::new(cnn.clone(), ExecMode::Packed(engine.clone()))), &ds)?;

    // 3. Deep CNN on MR-Overpacking (6 mults per DSP, approximate).
    let engine6 = GemmEngine::logical(PackingConfig::overpack6_int4(), Correction::MrRestore)?;
    serve(Arc::new(PackedNnBackend::new(cnn.clone(), ExecMode::Packed(engine6.clone()))), &ds)?;

    // 4. Adaptive precision routing: per-request error budgets (the
    //    appended metadata channel) split traffic between the two
    //    fabrics. A shared plan-cache budget accounts both replicas'
    //    resident planes (generous here; shrink it to watch LRU eviction
    //    kick in — serving stays bit-identical, just re-plans).
    let adaptive = Arc::new(AdaptiveBackend::new(
        cnn,
        ExecMode::Packed(engine.clone()),
        ExecMode::Packed(engine6.clone()),
        BudgetChannelPolicy { threshold: 0.5 },
        true,
    ));
    let plan_budget = PlanBudget::new(1 << 20);
    adaptive.exact_model().attach_plan_budget(&plan_budget);
    adaptive.dense_model().attach_plan_budget(&plan_budget);

    // Acceptance: with exact-precision budgets, the adaptive backend's
    // packed output is bit-identical to the exact reference — through
    // all three conv stages and the head.
    let exact_batch: Vec<Vec<f32>> = ds.images.iter().map(|i| with_budget(i, 0.0)).collect();
    let (adaptive_preds, _) = adaptive.infer(&exact_batch)?;
    let (exact_preds, _) =
        adaptive.exact_model().classify_images(&ds.images, &ExecMode::Exact)?;
    assert_eq!(
        adaptive_preds, exact_preds,
        "adaptive exact route must be bit-identical to the exact backend"
    );
    // Snapshot the routing counters so the served-stream split below
    // excludes this assertion batch.
    let (exact_before, dense_before) = (
        adaptive.exact_routed.load(std::sync::atomic::Ordering::Relaxed),
        adaptive.dense_routed.load(std::sync::atomic::Ordering::Relaxed),
    );

    // Serve a mixed stream: half the requests tolerate approximation.
    let ds_adaptive = data::Dataset {
        images: ds
            .images
            .iter()
            .enumerate()
            .map(|(i, img)| with_budget(img, if i % 2 == 0 { 0.0 } else { 1.0 }))
            .collect(),
        ..ds.clone()
    };
    serve(adaptive.clone(), &ds_adaptive)?;
    println!(
        "    adaptive routing: {} exact / {} dense; plan cache {} B resident ({} plans, {} evictions)",
        adaptive.exact_routed.load(std::sync::atomic::Ordering::Relaxed) - exact_before,
        adaptive.dense_routed.load(std::sync::atomic::Ordering::Relaxed) - dense_before,
        plan_budget.resident_bytes(),
        plan_budget.resident_plans(),
        plan_budget.evictions(),
    );

    // 5. The MLP comparison rows (JAX-trained weights, exported at
    //    `make artifacts` time); skipped gracefully when not built.
    match dsp_packing::runtime::PjrtRuntime::artifact_path("mlp_weights.txt") {
        Some(weights_path) => {
            let mut mlp = weights::mlp_from_export(&weights_path)?;
            let cal = mlp.quantize_batch(&ds.images[..32].to_vec())?;
            mlp.calibrate(&cal)?;
            serve(Arc::new(PackedNnBackend::new(mlp.clone(), ExecMode::Exact)), &ds)?;
            serve(Arc::new(PackedNnBackend::new(mlp.clone(), ExecMode::Packed(engine))), &ds)?;
            serve(Arc::new(PackedNnBackend::new(mlp, ExecMode::Packed(engine6))), &ds)?;
        }
        None => println!("mlp backends                skipped: run `make artifacts` first"),
    }

    // 6. PJRT: the AOT JAX artifacts (exact and packed-kernel variants).
    for name in ["mlp_exact.hlo.txt", "mlp_packed.hlo.txt"] {
        match PjrtBackend::load(name, 16, 64, 4) {
            Ok(b) => serve(Arc::new(b), &ds)?,
            Err(e) => println!("pjrt:{name:<21} skipped: {e}"),
        }
    }

    println!("\nreading: the packed deep CNN matches exact accuracy (full correction");
    println!("is bit-exact through every conv stage, pool and head) at 4x DSP");
    println!("utilization, with all filter banks planned once and resident across");
    println!("all {REQUESTS} requests; MR-Overpacking trades ~0 accuracy on this model");
    println!("for 6x, and the adaptive router serves both fabrics per request");
    println!("under one plan-cache byte budget. The MLP and PJRT rows put the");
    println!("original dense stack on the same workload.");
    Ok(())
}
