//! End-to-end driver (experiment E9): serve quantized **CNN** inference
//! through the full stack — a conv → max-pool → dense-head model lowered
//! to the packed GEMM via im2col — and compare backends on the same
//! workload:
//!
//! * **cnn:exact** — the quantized CNN on the exact i32 reference path.
//! * **cnn:packed:xilinx-int4** — the same CNN on the Rust virtual
//!   accelerator: bit-accurate DSP48E2 slices running INT4 packing with
//!   full correction (bit-identical logits to `cnn:exact`).
//! * **cnn:packed:overpack6-int4** — MR-Overpacking, six multiplications
//!   per DSP, small bounded approximation error.
//! * **exact / packed:...** — the original MLP backends on the same
//!   dataset, for cross-model comparison (requires `make artifacts` for
//!   the JAX-trained weights; skipped otherwise).
//! * **pjrt:...** — the AOT JAX/Pallas artifacts via PJRT, when built.
//!
//! Every backend serves the identical synthetic dataset (shared SplitMix64
//! generator, seed 7) through the L3 coordinator with dynamic batching.
//! Reported: accuracy, throughput, latency percentiles, DSP utilization.
//!
//! ```text
//! cargo run --release --example cnn_inference           # CNN rows always run
//! make artifacts && cargo run --release --example cnn_inference  # + MLP/PJRT
//! ```

use dsp_packing::coordinator::{
    Coordinator, InferenceBackend, PackedNnBackend, Request, ServerConfig,
};
use dsp_packing::correct::Correction;
use dsp_packing::gemm::GemmEngine;
use dsp_packing::nn::{data, weights, ExecMode, QuantCnn};
use dsp_packing::packing::PackingConfig;
use dsp_packing::runtime::PjrtBackend;
use std::sync::Arc;
use std::time::Instant;

const REQUESTS: usize = 512;

fn serve(backend: Arc<dyn InferenceBackend>, ds: &data::Dataset) -> dsp_packing::Result<()> {
    let name = backend.name().to_string();
    let coord = Coordinator::start(backend, ServerConfig::default());
    let handle = coord.handle();

    // Concurrent clients to keep the batcher busy.
    let start = Instant::now();
    let n_clients = 4;
    let per_client = REQUESTS / n_clients;
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let handle = handle.clone();
        let images = ds.images.clone();
        let labels = ds.labels.clone();
        clients.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..per_client {
                let idx = (c * per_client + i) % images.len();
                let pred = handle
                    .infer(Request { id: (c * per_client + i) as u64, image: images[idx].clone() })
                    .expect("infer");
                if pred.class == labels[idx] {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let elapsed = start.elapsed();
    let m = coord.shutdown();

    println!(
        "{name:<26} acc={:>5.1}%  thrpt={:>7.0} req/s  p50={:>6}us p99={:>6}us  batch={:.1}  dsp-util={:.2}",
        100.0 * correct as f64 / REQUESTS as f64,
        REQUESTS as f64 / elapsed.as_secs_f64(),
        m.p50_latency_us,
        m.p99_latency_us,
        m.mean_batch,
        m.dsp_utilization,
    );
    Ok(())
}

fn main() -> dsp_packing::Result<()> {
    // The dataset both sides agree on (seed 7, bit-identical generators).
    let ds = data::synthetic(256, 4, 64, 0.15, 7);

    println!("end-to-end inference, {REQUESTS} requests, 4 concurrent clients\n");

    // The quantized CNN: 3×3 conv (8 filters) → 2×2 max-pool → centroid
    // head, filter bank planned once into resident weight planes. Built
    // from the synthetic dataset — no artifacts required.
    let cnn = QuantCnn::new(&ds, 8, 4, 4, 17)?;

    // 1. CNN on the exact i32 reference.
    serve(Arc::new(PackedNnBackend::new(cnn.clone(), ExecMode::Exact)), &ds)?;

    // 2. CNN on the virtual accelerator: INT4 packing + full correction.
    let engine = GemmEngine::new(PackingConfig::int4(), Correction::FullRoundHalfUp)?;
    serve(Arc::new(PackedNnBackend::new(cnn.clone(), ExecMode::Packed(engine.clone()))), &ds)?;

    // 3. CNN on MR-Overpacking (6 mults per DSP, approximate).
    let engine6 = GemmEngine::logical(PackingConfig::overpack6_int4(), Correction::MrRestore)?;
    serve(Arc::new(PackedNnBackend::new(cnn, ExecMode::Packed(engine6.clone()))), &ds)?;

    // 4. The MLP comparison rows (JAX-trained weights, exported at
    //    `make artifacts` time); skipped gracefully when not built.
    match dsp_packing::runtime::PjrtRuntime::artifact_path("mlp_weights.txt") {
        Some(weights_path) => {
            let mut mlp = weights::mlp_from_export(&weights_path)?;
            let cal = mlp.quantize_batch(&ds.images[..32].to_vec())?;
            mlp.calibrate(&cal)?;
            serve(Arc::new(PackedNnBackend::new(mlp.clone(), ExecMode::Exact)), &ds)?;
            serve(Arc::new(PackedNnBackend::new(mlp.clone(), ExecMode::Packed(engine))), &ds)?;
            serve(Arc::new(PackedNnBackend::new(mlp, ExecMode::Packed(engine6))), &ds)?;
        }
        None => println!("mlp backends                skipped: run `make artifacts` first"),
    }

    // 5. PJRT: the AOT JAX artifacts (exact and packed-kernel variants).
    for name in ["mlp_exact.hlo.txt", "mlp_packed.hlo.txt"] {
        match PjrtBackend::load(name, 16, 64, 4) {
            Ok(b) => serve(Arc::new(b), &ds)?,
            Err(e) => println!("pjrt:{name:<21} skipped: {e}"),
        }
    }

    println!("\nreading: the packed CNN matches exact accuracy (full correction is");
    println!("bit-exact through conv, pool and head) at 4x DSP utilization, with the");
    println!("filter bank planned once and resident across all {REQUESTS} requests;");
    println!("MR-Overpacking trades ~0 accuracy on this model for 6x. The MLP and");
    println!("PJRT rows put the original dense stack on the same workload.");
    Ok(())
}
