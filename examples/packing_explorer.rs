//! Packing-configuration explorer: enumerate every INT-N packing that fits
//! the DSP48E2, compute the Fig. 9 density for each, measure the actual
//! error of the Pareto-optimal ones, and print the frontier.
//!
//! ```text
//! cargo run --release --example packing_explorer
//! ```

use dsp_packing::analysis::{exhaustive, sampled, OperandIter};
use dsp_packing::correct::Correction;
use dsp_packing::density;
use dsp_packing::dsp48::DspGeometry;
use dsp_packing::packing::PackedMultiplier;

fn main() -> dsp_packing::Result<()> {
    let g = DspGeometry::DSP48E2;

    println!("== Fig. 9 reference points ==");
    for p in density::fig9_points() {
        println!(
            "  {:<14} {} mults, rho = {:.3}{}",
            p.name,
            p.mults,
            p.density,
            if p.approximate { "  (approximate)" } else { "" }
        );
    }

    println!("\n== enumerating uniform INT-N configurations (delta in [-3, 3]) ==");
    let all = density::enumerate(&g, -3..=3);
    println!("{} configurations fit the DSP48E2", all.len());

    let front = density::pareto(&all);
    println!("\n== Pareto frontier (mults / precision / delta), with measured error ==");
    println!(
        "{:<26} {:>5} {:>5} {:>6} {:>7}   {:>8} {:>8}",
        "config", "mults", "prec", "delta", "rho", "MAE", "EP%"
    );
    for s in front.iter().take(12) {
        // Measure the real error of this configuration (exhaustive when
        // small, sampled otherwise). MR restoration for overpacked ones.
        let corr = if s.delta < 0 { Correction::MrRestore } else { Correction::None };
        let mul = PackedMultiplier::new(s.config.clone(), corr)
            .or_else(|_| PackedMultiplier::logical(s.config.clone(), corr))?;
        let space = OperandIter::cardinality(&s.config.a) * OperandIter::cardinality(&s.config.w);
        let report =
            if space <= 1 << 22 { exhaustive(&mul) } else { sampled(&mul, 2_000_000, 42) };
        println!(
            "{:<26} {:>5} {:>5} {:>6} {:>7.3}   {:>8.3} {:>7.2}%",
            s.name,
            s.mults,
            s.a_width.min(s.w_width),
            s.delta,
            s.density,
            report.mae_bar(),
            report.ep_bar_percent()
        );
    }

    println!("\nreading: delta >= 0 rows are exact with full/C-port correction;");
    println!("delta < 0 rows trade MAE for density > 1 (the Overpacking story).");
    Ok(())
}
