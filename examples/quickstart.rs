//! Quickstart: pack four 4-bit multiplications into one simulated DSP48E2,
//! see the §V floor error appear, and fix it three different ways.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use dsp_packing::analysis::exhaustive;
use dsp_packing::correct::Correction;
use dsp_packing::packing::{PackedMultiplier, PackingConfig};

fn main() -> dsp_packing::Result<()> {
    // The Xilinx INT4 configuration (wp521): a = two unsigned 4-bit
    // activations, w = two signed 4-bit weights, four products per DSP.
    let a = [3i128, 10];
    let w = [-7i128, 5];

    println!("packing a = {a:?} (u4), w = {w:?} (s4) into one DSP48E2\n");
    println!("expected outer product [a0w0, a1w0, a0w1, a1w1]: [-21, -70, 15, 50]\n");

    for corr in [
        Correction::None,
        Correction::FullRoundHalfUp,
        Correction::ApproxCPort,
    ] {
        let mul = PackedMultiplier::new(PackingConfig::int4(), corr)?;
        let r = mul.multiply(&a, &w)?;
        println!("{corr:?}: {r:?}");
    }

    // The raw scheme loses 1 on a1w0 (sign bits of a0w0 alias into the
    // field below it — §V). Both corrections restore it; the C-port one
    // costs zero fabric.

    // Overpacking: squeeze the same four multiplications into fewer bits
    // (δ = −2), then restore the contaminated MSBs (§VI-B).
    println!("\nOverpacking δ=−2 (fields overlap by 2 bits):");
    let cfg = PackingConfig::overpack_int4(-2)?;
    let raw = PackedMultiplier::new(cfg.clone(), Correction::None)?;
    println!("  raw:        {:?}  <- MSB corruption", raw.multiply(&[10, 3], &[-7, -4])?);
    let mr = PackedMultiplier::new(cfg, Correction::MrRestore)?;
    println!("  MR-restore: {:?}  <- the paper's §VI-B example", mr.multiply(&[10, 3], &[-7, -4])?);

    // Exhaustive error statistics (the Table I methodology) in one call:
    println!("\nexhaustive error analysis over all 65536 input combinations:");
    for corr in [Correction::None, Correction::ApproxCPort] {
        let mul = PackedMultiplier::new(PackingConfig::int4(), corr)?;
        println!("  {}", exhaustive(&mul).row());
    }
    Ok(())
}
